"""repro.analysis: rule registry, HLO structure parsing, mutation self-tests.

Convention (see ANALYSIS.md): every rule ships with at least one *mutation*
test — a deliberately broken lowering (doctored HLO, a mis-traced jaxpr, or
an over-counting jit cache) the rule must flag — next to the clean fixture
it must pass.  A rule without a mutation test is assumed vacuous.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo as H
from repro.analysis.rules import (
    RULES,
    CompileCounter,
    Finding,
    LintContext,
    combine_window,
    register_rule,
    run_rules,
)
from repro.configs import get_config
from repro.core import (
    MetaConfig,
    TopologyConfig,
    UpdateConfig,
    init_state,
    make_meta_step,
)
from repro.data import SineTaskSource
from repro.launch import steps as S
from repro.models.simple import SineMLP


# ---------------------------------------------------------------------------
# Handcrafted HLO fixtures (K=4 ring, deg=2, shard = 1000 u16 elems = 2000 B)
# ---------------------------------------------------------------------------

_K4_WIRE_HLO = textwrap.dedent("""
    HloModule wire_fixture

    ENTRY %main (p0: f32[16]) -> f32[16] {
      %p0 = f32[16]{0} parameter(0)
      %cp0 = u16[1000]{0} collective-permute(%x0), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
      %cp1 = u16[1000]{0} collective-permute(%x1), source_target_pairs={{0,3},{1,0},{2,1},{3,2}}
      %cpr = f32[300]{0} collective-permute(%x2), source_target_pairs={{0,1},{1,0}}
    }
""")

_COND_HLO = textwrap.dedent("""
    HloModule cond_fixture, input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, may-alias) }

    %noop_branch (np0: u16[1000]) -> u16[1000] {
      %np0 = u16[1000]{0} parameter(0)
      ROOT %ncopy = u16[1000]{0} copy(%np0)
    }

    %combine_branch (cp0.p: u16[1000]) -> u16[1000] {
      %cp0.p = u16[1000]{0} parameter(0)
      %mix = f32[4,16]{1,0} dot(f32[4,4]{1,0} %A, f32[4,16]{1,0} %W), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %w0 = u16[1000]{0} collective-permute(%cp0.p), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
      ROOT %w1 = u16[1000]{0} collective-permute(%w0), source_target_pairs={{0,3},{1,0},{2,1},{3,2}}
    }

    ENTRY %main (e0: u16[1000], epred: pred[]) -> u16[1000] {
      %e0 = u16[1000]{0} parameter(0)
      %epred = pred[] parameter(1)
      ROOT %gate = u16[1000]{0} conditional(%epred, %e0, %e0), branch_computations={%noop_branch, %combine_branch}
    }
""")


def _wire_ctx(hlo, **kw):
    base = dict(hlo=hlo, n_dev=4, K=4, degree=2, shard_bytes=2000,
                wire_dtype="bfloat16")
    base.update(kw)
    return LintContext(**base)


# ---------------------------------------------------------------------------
# collective-budget
# ---------------------------------------------------------------------------


def test_collective_budget_clean_fixture_passes_and_records():
    rep = run_rules(_wire_ctx(_K4_WIRE_HLO), only=["collective-budget"])
    assert rep.checked == ["collective-budget"] and rep.ok
    rec = rep.records["collective-budget"]
    # the window reads the u16 slice only — resharding f32 bytes excluded
    assert rec["permute_bytes"] == 2 * 2000
    assert rec["all_permute_bytes"] == 2 * 2000 + 300 * 4
    assert rec["expected_permute_bytes"] == 2 * 2000


def test_collective_budget_flags_missing_combine_mutation():
    # mutation: shrink the combine permutes 4× — wire below deg·shard
    broken = _K4_WIRE_HLO.replace("u16[1000]", "u16[250]")
    rep = run_rules(_wire_ctx(broken), only=["collective-budget"])
    assert not rep.ok and "below" in rep.findings[0].message


def test_collective_budget_flags_k_scaling_mutation():
    # mutation: the dense all-gather pattern — permutes ship 4× the shard
    broken = _K4_WIRE_HLO.replace("u16[1000]", "u16[4000]")
    rep = run_rules(_wire_ctx(broken), only=["collective-budget"])
    assert not rep.ok and "above" in rep.findings[0].message


def test_collective_budget_flags_ceiling_mutation():
    rep = run_rules(_wire_ctx(_K4_WIRE_HLO, budget_ceiling=100),
                    only=["collective-budget"])
    assert not rep.ok
    assert any("ceiling" in f.message for f in rep.findings)
    # the window itself is still clean — exactly one finding
    assert len(rep.findings) == 1


def test_combine_window_totals_match_hlo():
    rec = combine_window(_K4_WIRE_HLO, 4, degree=2, shard_bytes=2000,
                         wire_dtype="bfloat16")
    assert rec["ok"] and rec["permute_count"] == 3
    assert rec["total_collective_bytes"] == 2 * 2000 + 300 * 4


# ---------------------------------------------------------------------------
# wire-dtype-leak
# ---------------------------------------------------------------------------


def test_wire_dtype_leak_clean_fixture_passes():
    rep = run_rules(_wire_ctx(_K4_WIRE_HLO), only=["wire-dtype-leak"])
    assert rep.checked == ["wire-dtype-leak"] and rep.ok


def test_wire_dtype_leak_flags_full_width_mutation():
    # mutation: the u16 bitcast dropped — payload rides as f32
    broken = _K4_WIRE_HLO.replace("u16[1000]", "f32[1000]")
    rep = run_rules(_wire_ctx(broken), only=["wire-dtype-leak"])
    assert not rep.ok
    assert "no u16 collective-permute traffic" in rep.findings[0].message


def test_wire_dtype_leak_flags_partial_leak_mutation():
    # mutation: one of the two combine rounds leaked to full width
    broken = _K4_WIRE_HLO.replace("%cp1 = u16[1000]", "%cp1 = f32[1000]")
    rep = run_rules(_wire_ctx(broken), only=["wire-dtype-leak"])
    assert not rep.ok and "leaked" in rep.findings[0].message


def test_wire_dtype_leak_skipped_without_bf16_wire():
    rep = run_rules(_wire_ctx(_K4_WIRE_HLO, wire_dtype="float32"),
                    only=["wire-dtype-leak"])
    assert rep.skipped == ["wire-dtype-leak"] and rep.checked == []


# ---------------------------------------------------------------------------
# conditional-comm
# ---------------------------------------------------------------------------


def _cond_ctx(hlo):
    return LintContext(hlo=hlo, K=4, combine_every=2,
                       wire_dtype="bfloat16")


def test_conditional_comm_clean_fixture_passes():
    rep = run_rules(_cond_ctx(_COND_HLO), only=["conditional-comm"])
    assert rep.checked == ["conditional-comm"] and rep.ok


def test_conditional_comm_flags_unconditional_mutation():
    # mutation: a combine dot hoisted into ENTRY — skipped steps pay it
    broken = _COND_HLO.replace(
        "%epred = pred[] parameter(1)",
        "%epred = pred[] parameter(1)\n"
        "  %hoist = f32[4,16]{1,0} dot(f32[4,4]{1,0} %A, f32[4,16]{1,0} %W)")
    rep = run_rules(_cond_ctx(broken), only=["conditional-comm"])
    assert not rep.ok
    assert any("unconditionally" in f.message for f in rep.findings)


def test_conditional_comm_flags_both_branches_hot_mutation():
    # mutation: the "skip" branch also permutes — the gate is vacuous
    broken = _COND_HLO.replace(
        "ROOT %ncopy = u16[1000]{0} copy(%np0)",
        "ROOT %ncopy = u16[1000]{0} collective-permute(%np0), "
        "source_target_pairs={{0,1},{1,0}}")
    rep = run_rules(_cond_ctx(broken), only=["conditional-comm"])
    assert not rep.ok
    assert any("branches" in f.message for f in rep.findings)


def test_conditional_comm_flags_unlowered_combine_mutation():
    # mutation: no K×K dot, no wire permutes anywhere — combine vanished
    broken = (_COND_HLO
              .replace("u16[1000]{0} collective-permute", "u16[1000]{0} copy")
              .replace(" dot(", " mul("))
    rep = run_rules(_cond_ctx(broken), only=["conditional-comm"])
    assert not rep.ok
    assert "not lowered at all" in rep.findings[0].message


def test_conditional_comm_flags_ungated_orphan_mutation():
    # mutation: the conditional is gone; markers exist but nothing gates them
    broken = _COND_HLO.replace(
        "ROOT %gate = u16[1000]{0} conditional(%epred, %e0, %e0), "
        "branch_computations={%noop_branch, %combine_branch}",
        "ROOT %gate = u16[1000]{0} copy(%e0)")
    rep = run_rules(_cond_ctx(broken), only=["conditional-comm"])
    assert not rep.ok
    assert any("no conditional gates" in f.message for f in rep.findings)


# ---------------------------------------------------------------------------
# donation-honored
# ---------------------------------------------------------------------------


def test_donation_honored_on_real_lowerings():
    def f(state, x):
        return (jax.tree.map(lambda a: a + x.sum(), state), x * 2)

    state = {"a": jax.ShapeDtypeStruct((128,), jnp.float32),
             "b": jax.ShapeDtypeStruct((64,), jnp.float32)}
    x = jax.ShapeDtypeStruct((8,), jnp.float32)
    good = jax.jit(f, donate_argnums=(0,)).lower(state, x).compile().as_text()
    rep = run_rules(LintContext(hlo=good, expected_aliases=2),
                    only=["donation-honored"])
    assert rep.checked == ["donation-honored"] and rep.ok
    assert rep.records["donation-honored"]["alias_entries"] >= 2
    # mutation: same program compiled WITHOUT donation — no aliases
    bad = jax.jit(f).lower(state, x).compile().as_text()
    rep_bad = run_rules(LintContext(hlo=bad, expected_aliases=2),
                        only=["donation-honored"])
    assert not rep_bad.ok
    assert "defensive copies" in rep_bad.findings[0].message


def test_donation_honored_fraction_threshold_on_fixture():
    # _COND_HLO's header declares exactly 2 alias entries
    ok = run_rules(LintContext(hlo=_COND_HLO, expected_aliases=2),
                   only=["donation-honored"])
    assert ok.ok
    short = run_rules(LintContext(hlo=_COND_HLO, expected_aliases=4),
                      only=["donation-honored"])
    assert not short.ok
    assert short.records["donation-honored"]["required"] == 4


# ---------------------------------------------------------------------------
# retrace-guard
# ---------------------------------------------------------------------------


def test_retrace_guard_clean_trace_passes():
    jaxpr = jax.make_jaxpr(lambda x, s: x * s)(
        jnp.ones(4), jnp.array(3.0, jnp.float32))
    rep = run_rules(LintContext(jaxpr=jaxpr), only=["retrace-guard"])
    assert rep.checked == ["retrace-guard"] and rep.ok


def test_retrace_guard_flags_weak_type_scalar_mutation():
    # mutation: a python float leaks into the trace as a weak-typed invar
    jaxpr = jax.make_jaxpr(lambda x, s: x * s)(jnp.ones(4), 3.0)
    rep = run_rules(LintContext(jaxpr=jaxpr), only=["retrace-guard"])
    assert not rep.ok and "weak-typed" in rep.findings[0].message


def test_retrace_guard_flags_host_callback_mutation():
    def f(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((4,), jnp.float32),
            x)
        return y + 1.0

    jaxpr = jax.make_jaxpr(f)(jnp.ones(4))
    rep = run_rules(LintContext(jaxpr=jaxpr), only=["retrace-guard"])
    assert not rep.ok
    assert "pure_callback" in rep.findings[0].message


def test_retrace_guard_flags_compile_count_overrun():
    counts = {"superstep": {"compiles": 3, "expected": 1, "dispatches": 8}}
    rep = run_rules(LintContext(compile_counts=counts),
                    only=["retrace-guard"])
    assert not rep.ok and "compiled 3×" in rep.findings[0].message
    # unknown cache sizes are tolerated, not treated as violations
    rep_none = run_rules(
        LintContext(compile_counts={"s": {"compiles": None, "expected": 1}}),
        only=["retrace-guard"])
    assert rep_none.ok


def test_compile_counter_reads_jit_cache():
    f = jax.jit(lambda x: x * 2)
    f(jnp.ones(4))
    c = CompileCounter(f)
    assert c.count() == 1
    f(jnp.ones(8))  # new shape → second compile
    assert c.count() == 2
    assert CompileCounter(object()).count() is None


# ---------------------------------------------------------------------------
# recompile-count regressions (the invariant behind the superstep driver)
# ---------------------------------------------------------------------------


def test_superstep_c8_compiles_exactly_once_across_dispatches():
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    K, C = 4, 8
    mcfg = MetaConfig(num_agents=K, tasks_per_agent=2, inner_lr=0.01,
                      outer_optimizer="sgd", outer_lr=5e-3,
                      update_config=UpdateConfig(strategy="atc"),
                      topology_config=TopologyConfig(graph="ring",
                                                     schedule="gossip",
                                                     seed=0))
    meta = make_meta_step(model.loss_fn, mcfg)

    def step_fn(st, b):
        return meta(st, b["support"], b["query"])

    source = SineTaskSource(K=K, tasks_per_agent=2, shots=5, seed=0)
    state = init_state(jax.random.key(0), model.init, mcfg)
    superstep = jax.jit(S.make_superstep(step_fn))
    for d in range(2):
        chunk = []
        for i in range(C):
            ep = source.sample(d * C + i)
            chunk.append({"support": jax.tree.map(jnp.asarray, ep.support),
                          "query": jax.tree.map(jnp.asarray, ep.query)})
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *chunk)
        state, _ = superstep(state, stacked)
    compiles = CompileCounter(superstep).count()
    assert compiles == 1, (
        f"superstep compiled {compiles}× across 2 same-shape dispatches — "
        f"something in the carried state retriggers tracing")


_DYNAMIC_RECOMPILE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.analysis.rules import CompileCounter
    from repro.core import diffusion, topology

    K, M = 8, 256
    mesh = compat.make_mesh((K,), ("data",))
    phi = {"w": jax.device_put(jnp.ones((K, M), jnp.float32),
                               NamedSharding(mesh, P("data", None)))}
    topo = topology.build_topology("ring", K)
    sched = topology.make_schedule("link_failure", topo, p=0.3, period=8,
                                   seed=0)
    with mesh:
        fn = jax.jit(diffusion.make_combine(
            "mesh_sparse_dynamic", A=sched.matrices, mesh=mesh,
            axis_name="data", in_specs={"w": P("data", None)}))
        for step in range(16):
            phi = fn(phi, jnp.asarray(step, jnp.int32))
        compiles = CompileCounter(fn).count()
    print("RECOMPILE_JSON:" + json.dumps(
        {"compiles": compiles, "dispatches": 16}))
""")


def test_mesh_sparse_dynamic_compiles_once_across_schedule():
    """16 steps across two periods of a link_failure schedule must hit one
    jit cache entry: the schedule is a traced gather, not a python branch."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _DYNAMIC_RECOMPILE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    lines = [ln for ln in res.stdout.splitlines()
             if ln.startswith("RECOMPILE_JSON:")]
    assert lines, res.stderr[-2000:]
    out = json.loads(lines[0][len("RECOMPILE_JSON:"):])
    assert out["compiles"] == 1, out


# ---------------------------------------------------------------------------
# hlo.py structure parsing
# ---------------------------------------------------------------------------


def test_parse_computations_and_entry():
    comps, entry = H.parse_computations(_COND_HLO)
    assert entry == "main"
    assert set(comps) == {"main", "noop_branch", "combine_branch"}
    assert len(comps["combine_branch"]) == 4


def test_reachable_stops_at_branches():
    comps, entry = H.parse_computations(_COND_HLO)
    assert H.reachable(comps, entry) == {"main", "noop_branch",
                                         "combine_branch"}
    assert H.reachable(comps, entry, include_branches=False) == {"main"}


def test_conditional_branch_forms():
    line = ("%c = f32[] conditional(%p, %a, %b), "
            "true_computation=%yes, false_computation=%no")
    assert H.conditional_branches(line) == ["yes", "no"]
    [gate] = H.conditional_lines(H.parse_computations(_COND_HLO)[0])
    assert H.conditional_branches(gate) == ["noop_branch", "combine_branch"]


def test_alias_entries_brace_matching():
    assert H.alias_entries(_COND_HLO) == 2
    assert H.alias_entries(_K4_WIRE_HLO) == 0


# ---------------------------------------------------------------------------
# registry plumbing
# ---------------------------------------------------------------------------


def test_registry_has_all_five_rules():
    assert set(RULES) >= {"collective-budget", "wire-dtype-leak",
                          "conditional-comm", "donation-honored",
                          "retrace-guard"}


def test_empty_context_skips_everything():
    rep = run_rules(LintContext())
    assert rep.checked == [] and set(rep.skipped) == set(RULES)
    assert rep.ok  # no rule ran, no finding — callers see skipped, not fail


def test_report_json_roundtrip():
    rep = run_rules(_wire_ctx(_K4_WIRE_HLO.replace("u16[1000]", "u16[250]")),
                    only=["collective-budget"])
    j = json.loads(json.dumps(rep.to_json()))
    assert j["ok"] is False and j["findings"][0]["rule"] == "collective-budget"
    assert j["records"]["collective-budget"]["permute_bytes"] == 2 * 500


def test_register_rule_and_only_selection():
    try:
        @register_rule("tmp-always", "test-only rule", lambda ctx: True)
        def _tmp(ctx):
            return [Finding("tmp-always", "fired")]

        rep = run_rules(LintContext(), only=["tmp-always"])
        assert [f.rule for f in rep.findings] == ["tmp-always"]
    finally:
        RULES.pop("tmp-always", None)


def test_every_registered_rule_has_a_description():
    for rule in RULES.values():
        assert rule.description and rule.name
