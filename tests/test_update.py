"""DiffusionStrategy × CommSchedule × TopologySchedule composition.

Covers the first-class decentralized-update API: strategy registry parity
against hand-written compositions, the nested MetaConfig surface (flat
fields as deprecated aliases), the lax.cond communication gating (skipped
steps execute no combine matmul — checked on the optimized HLO), and
stacked matrix schedules through the combine backends.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (MetaConfig, TopologyConfig, UpdateConfig, diffusion,
                        init_state, make_meta_step, maml, topology, update)
from repro.core.meta_trainer import (combination_matrix_for, schedule_for,
                                     topology_for)
from repro.data import SineTaskSource
from repro.models.simple import SineMLP
from repro.optim import get_optimizer

K = 6


@pytest.fixture(scope="module")
def sine_model():
    return SineMLP(get_config("sine_mlp"))


@pytest.fixture(scope="module")
def episodes():
    src = SineTaskSource(K=K, tasks_per_agent=2, shots=10, seed=0)
    eps = [src.sample(i) for i in range(4)]
    return [(jax.tree.map(jnp.asarray, e.support),
             jax.tree.map(jnp.asarray, e.query)) for e in eps]


def _phi(seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (K, 7, 5)),
            "b": jax.random.normal(k2, (K, 3))}


def _nested(strategy, schedule="static", graph="ring", every=1, **kw):
    return MetaConfig(
        num_agents=K, tasks_per_agent=2, inner_lr=0.01,
        outer_optimizer="sgd", outer_lr=5e-3,
        update_config=UpdateConfig(strategy=strategy, combine_every=every),
        topology_config=TopologyConfig(graph=graph, schedule=schedule, **kw))


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

def test_strategy_registry_contents():
    names = update.update_strategies()
    for expected in ("atc", "cta", "consensus", "none", "centralized"):
        assert expected in names
    with pytest.raises(ValueError, match="registered"):
        update.get_strategy("bogus")
    assert update.get_strategy("cta").pre_combine
    assert not update.get_strategy("none").communicates
    assert not update.get_strategy("centralized").needs_combine_fn


def test_inner_algo_registry():
    for expected in ("maml", "fomaml", "reptile"):
        assert expected in update.inner_algos()
    assert update.get_inner_algo("maml").order == 2
    assert update.get_inner_algo("fomaml").order == 1
    with pytest.raises(ValueError, match="registered"):
        update.get_inner_algo("bogus")


def test_comm_schedule():
    always = update.CommSchedule()
    assert always.always
    s = update.CommSchedule(every=3)
    assert not s.always
    assert [bool(s.is_comm_step(i)) for i in range(6)] == [
        False, False, True, False, False, True]
    with pytest.raises(ValueError, match=">= 1"):
        update.CommSchedule(every=0)


# ---------------------------------------------------------------------------
# Strategy compositions == hand-written formulas
# ---------------------------------------------------------------------------

def test_strategy_compositions_match_handwritten():
    A = topology.combination_matrix(K, "ring")
    combine = diffusion.make_combine("dense", A=A)
    params, updates = _phi(0), _phi(1)
    plus = jax.tree.map(lambda p, u: p + u, params, updates)

    atc = update.get_strategy("atc").apply(params, updates, combine, 0)
    ref = diffusion.dense_combine(jnp.asarray(A), plus)
    for a, b in zip(jax.tree.leaves(atc), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    con = update.get_strategy("consensus").apply(params, updates, combine, 0)
    ref = jax.tree.map(lambda m, u: m + u,
                       diffusion.dense_combine(jnp.asarray(A), params),
                       updates)
    for a, b in zip(jax.tree.leaves(con), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    non = update.get_strategy("none").apply(params, updates, None, 0)
    for a, b in zip(jax.tree.leaves(non), jax.tree.leaves(plus)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    cen = update.get_strategy("centralized").apply(params, updates, None, 0)
    ref = jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                   x.shape), plus)
    for a, b in zip(jax.tree.leaves(cen), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Trainer parity: the assembled step == hand-written compositions, bitwise
# ---------------------------------------------------------------------------

def _run_trainer(model, mcfg, episodes, steps=3):
    state = init_state(jax.random.key(0), model.init, mcfg,
                       identical_init=False)
    step = jax.jit(make_meta_step(model.loss_fn, mcfg))
    for sup, qry in episodes[:steps]:
        state, metrics = step(state, sup, qry)
    return state, metrics


def _run_handwritten(model, strategy, episodes, steps=3):
    """The strategy compositions spelled out with the raw pieces — the
    'current trainer' formulas the new assembly must reproduce bitwise."""
    mcfg = _nested("atc")     # only init/opt hyperparams are read
    opt = get_optimizer("sgd", 5e-3)
    state = init_state(jax.random.key(0), model.init, mcfg,
                       identical_init=False)
    A = jnp.asarray(topology.combination_matrix(K, "ring"))

    def per_agent(p, s, q):
        return maml.multi_task_meta_grad(model.loss_fn, p, s, q, alpha=0.01)

    @jax.jit
    def step(params, opt_state, sup, qry):
        base = params
        if strategy == "cta":
            base = diffusion.dense_combine(A, params)
        losses, grads = jax.vmap(per_agent)(base, sup, qry)
        updates, opt_state = opt.update(grads, opt_state, base)
        if strategy == "atc":
            new = diffusion.atc_step(base, updates,
                                     lambda p: diffusion.dense_combine(A, p))
        elif strategy == "consensus":
            new = diffusion.cta_step(base, updates,
                                     lambda p: diffusion.dense_combine(A, p))
        else:                  # cta: mixed before the gradient, local apply
            new = jax.tree.map(lambda p, u: p + u, base, updates)
        return new, opt_state

    params, opt_state = state.params, state.opt_state
    for sup, qry in episodes[:steps]:
        params, opt_state = step(params, opt_state, sup, qry)
    return params


@pytest.mark.parametrize("strategy", ["atc", "cta", "consensus"])
def test_trainer_bit_identical_to_handwritten(sine_model, episodes, strategy):
    state, _ = _run_trainer(sine_model, _nested(strategy), episodes)
    ref = _run_handwritten(sine_model, strategy, episodes)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flat_config_path_identical_to_nested(sine_model, episodes):
    """Legacy flat MetaConfig(combine='dense', ...) trains bit-identically
    to the nested atc/static construction (same seed, same metrics)."""
    with pytest.warns(DeprecationWarning):
        flat = MetaConfig(num_agents=K, tasks_per_agent=2, inner_lr=0.01,
                          mode="maml", combine="dense", topology="ring",
                          outer_optimizer="sgd", outer_lr=5e-3)
    sa, ma = _run_trainer(sine_model, flat, episodes)
    sb, mb = _run_trainer(sine_model, _nested("atc"), episodes)
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ma["loss"]),
                                  np.asarray(mb["loss"]))


def test_strategies_produce_distinct_iterates(sine_model, episodes):
    outs = {}
    for strategy in ["atc", "cta", "consensus", "none", "centralized"]:
        state, _ = _run_trainer(sine_model, _nested(strategy), episodes)
        outs[strategy] = np.concatenate(
            [np.asarray(x).ravel() for x in jax.tree.leaves(state.params)])
    names = list(outs)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            assert not np.array_equal(outs[a], outs[b]), (a, b)


def test_single_agent_degenerates_to_local(sine_model, episodes):
    mcfg = MetaConfig(num_agents=1, tasks_per_agent=2, inner_lr=0.01,
                      outer_optimizer="sgd", outer_lr=5e-3,
                      update_config=UpdateConfig(strategy="atc"),
                      topology_config=TopologyConfig(graph="ring"))
    one_ep = [(jax.tree.map(lambda x: x[:1], s),
               jax.tree.map(lambda x: x[:1], q)) for s, q in episodes]
    state, metrics = _run_trainer(sine_model, mcfg, one_ep)
    assert float(metrics["disagreement"]) == 0.0


# ---------------------------------------------------------------------------
# CommSchedule: skipped steps really skip the combine
# ---------------------------------------------------------------------------

def test_combine_every_skips_then_communicates(sine_model, episodes):
    """Before the first comm step the gated run is bit-identical to the
    non-cooperative baseline; on the comm step it diverges (communication
    happened), matching the atc composition applied at that step."""
    gated = _nested("atc", every=3)
    s_gated = init_state(jax.random.key(0), sine_model.init, gated,
                         identical_init=False)
    s_non = s_gated
    step_g = jax.jit(make_meta_step(sine_model.loss_fn, gated))
    step_n = jax.jit(make_meta_step(sine_model.loss_fn, _nested("none")))
    for i, (sup, qry) in enumerate(episodes[:3]):
        s_gated, _ = step_g(s_gated, sup, qry)
        s_non, _ = step_n(s_non, sup, qry)
        diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                   for a, b in zip(jax.tree.leaves(s_gated.params),
                                   jax.tree.leaves(s_non.params)))
        if i < 2:
            # distinct compiled programs: allow fusion-level float noise
            assert diff < 1e-8, f"step {i} should not communicate ({diff})"
        else:
            assert diff > 1e-6, "step 2 must run the combine"


def test_combine_every_hlo_has_no_unconditional_combine(sine_model):
    """Regression for the jnp.where path: with combine_every > 1 the K×K
    combine matmul must live only inside a conditional branch — the
    skipped-step execution path contains no contraction over the agent
    axis (and no collective).  The invariant itself lives in the
    conditional-comm lint rule (repro.analysis); this test binds it to a
    real lowered meta step."""
    from repro.analysis.rules import LintContext, run_rules

    mcfg = _nested("atc", every=2)
    step = make_meta_step(sine_model.loss_fn, mcfg)
    src = SineTaskSource(K=K, tasks_per_agent=2, shots=10, seed=0)
    ep = src.sample(0)
    sup = jax.tree.map(jnp.asarray, ep.support)
    qry = jax.tree.map(jnp.asarray, ep.query)
    state = init_state(jax.random.key(0), sine_model.init, mcfg)
    text = jax.jit(step).lower(state, sup, qry).compile().as_text()

    ctx = LintContext(hlo=text, K=K, combine_every=2)
    report = run_rules(ctx, only=["conditional-comm"])
    assert report.checked == ["conditional-comm"]
    assert report.ok, [f.message for f in report.findings]

    # the rule must not be vacuous here: the combine dot exists in this
    # module, so a gutted matcher would have tripped the no-markers branch
    assert f"f32[{K},{K}]" in text


# ---------------------------------------------------------------------------
# Stacked matrix schedules through the combine backends
# ---------------------------------------------------------------------------

def test_dense_combine_indexes_stacked_schedule():
    topo = topology.build_topology("ring", K)
    sched = topology.make_schedule("link_failure", topo, p=0.4, period=5,
                                   seed=3)
    stack = sched.stacked()
    assert stack.shape == (5, K, K)
    combine = diffusion.make_combine("dense", A=stack)
    phi = _phi(2)
    for step in [0, 2, 7]:                     # 7 wraps to 7 % 5 == 2
        out = combine(phi, jnp.int32(step))
        ref = diffusion.dense_combine(jnp.asarray(sched.matrix_at(step)), phi)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
    with pytest.raises(ValueError, match="step"):
        combine(phi)                           # stacked schedule needs step


def test_sparse_backends_reject_stacked_schedule():
    stack = np.stack([topology.combination_matrix(K, "ring")] * 3)
    for name in ["sparse_host", "sparse", "mesh_sparse"]:
        # the error points at the dynamic sibling that CAN serve the stack
        with pytest.raises(ValueError, match=f"{name}_dynamic"):
            diffusion.make_combine(name, A=stack, axis_name="data",
                                   mesh="unused")
    # auto-selection prefers the sparse dynamic lowering over dense
    assert diffusion.select_backend(stack) == "sparse_host_dynamic"


def test_trainer_with_dynamic_schedules_contracts(sine_model, episodes):
    for schedule in ["link_failure", "gossip", "round_robin"]:
        mcfg = _nested("atc", schedule=schedule, link_failure_p=0.3)
        state, metrics = _run_trainer(sine_model, mcfg, episodes, steps=4)
        assert np.isfinite(float(metrics["loss"]))
        # any mixing schedule beats no mixing on disagreement
        s_non, m_non = _run_trainer(sine_model, _nested("none"), episodes,
                                    steps=4)
        assert (float(metrics["disagreement"])
                < float(m_non["disagreement"])), schedule


# ---------------------------------------------------------------------------
# Nested MetaConfig + deprecated flat aliases
# ---------------------------------------------------------------------------

def test_flat_fields_warn_and_map():
    with pytest.warns(DeprecationWarning, match="nested"):
        m = MetaConfig(num_agents=4, combine="centralized", topology="ring")
    assert m.update_config.strategy == "centralized"
    assert m.topology_config.graph == "ring"
    with pytest.warns(DeprecationWarning):
        m = MetaConfig(num_agents=4, combine="none")
    assert m.update_config.strategy == "none"
    with pytest.warns(DeprecationWarning):
        m = MetaConfig(num_agents=4, combine="sparse_host", mode="fomaml",
                       combine_every=4)
    assert m.update_config == UpdateConfig(strategy="atc", inner="fomaml",
                                           backend="sparse_host",
                                           combine_every=4)


def test_nested_config_is_silent_and_mirrors_flat():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        m = MetaConfig(num_agents=4,
                       update_config=UpdateConfig(strategy="cta",
                                                  inner="fomaml",
                                                  combine_every=2),
                       topology_config=TopologyConfig(graph="torus",
                                                      rule="uniform"))
    # legacy readers of the flat fields keep seeing the truth
    assert m.mode == "fomaml"
    assert m.topology == "torus"
    assert m.comb_rule == "uniform"
    assert m.combine_every == 2
    m2 = MetaConfig(update_config=UpdateConfig(strategy="none"),
                    topology_config=TopologyConfig())
    assert m2.combine == "none"


def test_defaults_construct_silently():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        MetaConfig(num_agents=3, outer_optimizer="adam")


def test_replace_on_flat_field_warns_about_conflict():
    """dataclasses.replace(cfg, mode=...) carries the nested configs over,
    so the flat value is discarded — loudly, not silently."""
    import dataclasses
    with pytest.warns(DeprecationWarning):
        cfg = MetaConfig(num_agents=4, mode="fomaml")
    # (a flat value equal to the field default is indistinguishable from
    # "not passed" and stays silent — only non-default conflicts can warn)
    with pytest.warns(DeprecationWarning, match="conflict"):
        cfg2 = dataclasses.replace(cfg, mode="reptile")
    assert cfg2.mode == "fomaml"        # nested configs won
    # replacing the nested config is the supported path: the value sticks.
    # A stale non-default flat mirror still triggers the conflict pointer
    # (replace() re-passes it), but the nested truth wins either way.
    with pytest.warns(DeprecationWarning, match="conflict"):
        cfg3 = dataclasses.replace(
            cfg, update_config=dataclasses.replace(cfg.update_config,
                                                   inner="maml"))
    assert cfg3.mode == "maml"
    # no stale mirror (flat at defaults) -> nested replace is silent
    base = MetaConfig(num_agents=4,
                      update_config=UpdateConfig(combine_every=1),
                      topology_config=TopologyConfig())
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg4 = dataclasses.replace(
            base, update_config=dataclasses.replace(base.update_config,
                                                    backend="pallas"))
    assert cfg4.combine == "pallas"


def test_schedule_backend_resolution_for_stacked():
    stack = np.stack([topology.combination_matrix(K, "ring")] * 3)
    # static sparse backends upgrade silently to their dynamic siblings
    # (same permute rounds + wire cost, step-gathered weights); dense/auto
    # and static matrices pass through untouched
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert diffusion.resolve_schedule_backend(
            "mesh_sparse", stack) == "mesh_sparse_dynamic"
        assert diffusion.resolve_schedule_backend(
            "sparse_host", stack) == "sparse_host_dynamic"
        assert diffusion.resolve_schedule_backend("dense", stack) == "dense"
        assert diffusion.resolve_schedule_backend("auto", stack) == "auto"
        assert diffusion.resolve_schedule_backend(
            "mesh_sparse", topology.combination_matrix(K, "ring")
        ) == "mesh_sparse"


def test_topology_typo_rejected_even_at_k1():
    with pytest.raises(ValueError, match="unknown topology"):
        topology.combination_matrix(1, "rng")
    with pytest.raises(ValueError, match="unknown topology"):
        topology.build_topology("rng", 1)


def test_topology_mismatch_fails_early_with_both_numbers():
    mcfg = MetaConfig(num_agents=4,
                      update_config=UpdateConfig(strategy="atc"),
                      topology_config=TopologyConfig(graph="paper"))
    with pytest.raises(ValueError) as ei:
        make_meta_step(lambda p, b: jnp.zeros(()), mcfg)
    msg = str(ei.value)
    assert "paper" in msg and "4" in msg and "6" in msg


def test_helpers_resolve_nested_config():
    m = _nested("atc", graph="ring")
    assert topology_for(m).name == "ring"
    np.testing.assert_allclose(combination_matrix_for(m),
                               topology.combination_matrix(K, "ring"))
    assert schedule_for(m).static
