"""Version-adaptive shims: the same calls must work on jax 0.4.x and >= 0.5."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat


def test_jax_version_parsed():
    assert isinstance(compat.JAX_VERSION, tuple)
    assert compat.JAX_VERSION >= (0, 4)


def test_abstract_mesh_both_generations():
    m = compat.abstract_mesh((16, 16), ("data", "model"))
    assert compat.mesh_axis_sizes(m) == {"data": 16, "model": 16}
    m3 = compat.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert compat.mesh_axis_sizes(m3) == {"pod": 2, "data": 16, "model": 16}


def test_make_mesh_drops_axis_types_when_unsupported():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    assert compat.mesh_axis_sizes(mesh) == {"data": 1, "model": 1}


def test_mesh_axis_sizes_concrete_mesh():
    mesh = compat.make_mesh((1,), ("data",))
    assert compat.mesh_axis_sizes(mesh) == {"data": 1}


def test_shard_map_wrapper_full_manual():
    mesh = compat.make_mesh((1,), ("data",))
    f = compat.shard_map(lambda x: x * 2, mesh, in_specs=P(), out_specs=P())
    np.testing.assert_array_equal(f(jnp.arange(3.0)), 2 * jnp.arange(3.0))


def test_shard_map_wrapper_partial_manual_under_jit():
    mesh = compat.make_mesh((1, 1), ("data", "model"))
    f = compat.shard_map(lambda x: x + jax.lax.axis_index("data"),
                         mesh, in_specs=P(), out_specs=P(),
                         axis_names={"data"})
    np.testing.assert_array_equal(jax.jit(f)(jnp.zeros(2)), jnp.zeros(2))


def test_tree_utils_roundtrip():
    tree = {"a": jnp.ones(2), "b": (jnp.zeros(1), jnp.ones(3))}
    leaves, treedef = compat.tree_flatten(tree)
    assert len(leaves) == len(compat.tree_leaves(tree)) == 3
    back = compat.tree_unflatten(treedef, leaves)
    assert compat.tree_structure(back) == compat.tree_structure(tree)
    doubled = compat.tree_map(lambda x: 2 * x, tree)
    np.testing.assert_array_equal(doubled["a"], 2 * jnp.ones(2))
