"""The unified TaskSource contract: canonical axes, per-agent domain
disjointness (heterogeneous π_k), and cross-instance determinism."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.data import (Episode, FewShotTaskSource, LMTaskSource,
                        SineTaskSource, TaskSource, partition_domains)


def make_sources():
    return [
        SineTaskSource(K=4, tasks_per_agent=3, shots=5, n_domains=16, seed=3),
        FewShotTaskSource(K=3, tasks_per_agent=2, n_classes=40, n_way=4,
                          k_shot=1, n_query=3, seed=3),
        LMTaskSource(vocab_size=256, seq_len=12, K=4, tasks_per_agent=2,
                     task_batch=3, n_domains=12, holdout_domains=2, seed=3),
    ]


SOURCE_IDS = ["sine", "fewshot", "lm"]


# ---------------------------------------------------------------------------
# partition_domains: the one sharding mechanism
# ---------------------------------------------------------------------------

def test_partition_domains_disjoint_and_covering():
    for n, K in [(16, 4), (13, 4), (5, 5), (64, 6)]:
        shards = partition_domains(n, K)
        assert len(shards) == K
        all_ids = np.concatenate(shards)
        assert sorted(all_ids.tolist()) == list(range(n))
        for i in range(K):
            for j in range(i + 1, K):
                assert not set(shards[i]) & set(shards[j])


def test_partition_domains_rejects_too_few_domains():
    with pytest.raises(ValueError, match="n_domains >= K"):
        partition_domains(3, 4)
    with pytest.raises(ValueError, match="at least one agent"):
        partition_domains(4, 0)


# ---------------------------------------------------------------------------
# Protocol conformance + canonical axes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_sources_conform_to_protocol(source):
    assert isinstance(source, TaskSource)
    assert source.n_domains >= source.K
    assert isinstance(source.heterogeneity, str)


@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_episode_canonical_leading_axes(source):
    ep = source.sample(0)
    K, T = source.K, source.tasks_per_agent
    for leaf in jax.tree.leaves(ep.support) + jax.tree.leaves(ep.query):
        assert leaf.shape[:2] == (K, T)
    assert ep.domains.shape[:2] == (K, T)
    assert ep.step == 0


def test_episode_shapes_per_source():
    sine, few, lm = make_sources()
    ep = sine.sample(1)
    assert ep.support[0].shape == (4, 3, 5, 1)       # (K, T, shots, 1)
    ep = few.sample(1)
    assert ep.support[0].shape == (3, 2, 4, few.dim)  # (K, T, way·shot, d)
    assert ep.query[0].shape == (3, 2, 12, few.dim)   # way·n_query rows
    ep = lm.sample(1)
    assert ep.support["tokens"].shape == (4, 2, 3, 12)
    assert ep.query["labels"].shape == (4, 2, 3, 12)
    assert ep.support["tokens"].max() < 256
    # labels are next-token shifted within each generated sequence
    np.testing.assert_array_equal(ep.support["tokens"][..., 1:],
                                  ep.support["labels"][..., :-1])


# ---------------------------------------------------------------------------
# Heterogeneity: pairwise-disjoint per-agent domain shards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_agent_streams_have_disjoint_shards(source):
    streams = source.sources()
    assert len(streams) == source.K
    for i in range(source.K):
        for j in range(i + 1, source.K):
            assert not set(streams[i].domains) & set(streams[j].domains), \
                f"agents {i} and {j} share domains"
    covered = sorted(np.concatenate([s.domains for s in streams]).tolist())
    n_train = getattr(source, "n_train_domains", source.n_domains)
    assert covered == list(range(n_train))


@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_episode_domains_drawn_from_own_shard(source):
    streams = source.sources()
    for step in range(3):
        ep = source.sample(step)
        for k, stream in enumerate(streams):
            drawn = set(np.asarray(ep.domains[k]).reshape(-1).tolist())
            assert drawn <= set(stream.domains.tolist()), \
                f"agent {k} drew outside its shard at step {step}"


@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_agent_stream_sample_is_stacked_slice(source):
    ep = source.sample(5)
    for k, stream in enumerate(source.sources()):
        sk = stream.sample(5)
        for a, b in zip(jax.tree.leaves(sk.support),
                        jax.tree.leaves(ep.support)):
            np.testing.assert_array_equal(a, b[k])
        np.testing.assert_array_equal(sk.domains, ep.domains[k])


def test_sources_rejects_mismatched_K():
    src = SineTaskSource(K=4, n_domains=16)
    with pytest.raises(ValueError, match="bound to K=4"):
        src.sources(K=6)


# ---------------------------------------------------------------------------
# Determinism: same seed ⇒ bit-identical episodes across instances
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_bit_identical_across_instances(source):
    clone = dataclasses.replace(source)
    for step in (0, 7):
        a, b = source.sample(step), clone.sample(step)
        for x, y in zip(jax.tree.leaves((a.support, a.query)),
                        jax.tree.leaves((b.support, b.query))):
            np.testing.assert_array_equal(x, y)
        np.testing.assert_array_equal(a.domains, b.domains)
    ea, eb = source.eval_sample(4, seed=11), clone.eval_sample(4, seed=11)
    for x, y in zip(jax.tree.leaves(ea.support), jax.tree.leaves(eb.support)):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("source", make_sources(), ids=SOURCE_IDS)
def test_steps_differ(source):
    a, b = source.sample(0), source.sample(1)
    assert any(not np.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a.support),
                               jax.tree.leaves(b.support)))


def test_seed_changes_episodes():
    a = LMTaskSource(vocab_size=256, seq_len=12, K=2, tasks_per_agent=2,
                     task_batch=2, n_domains=8, seed=0).sample(0)
    b = LMTaskSource(vocab_size=256, seq_len=12, K=2, tasks_per_agent=2,
                     task_batch=2, n_domains=8, seed=1).sample(0)
    assert not np.array_equal(a.support["tokens"], b.support["tokens"])


# ---------------------------------------------------------------------------
# Eval episodes: full / held-out universe, task-leading axes
# ---------------------------------------------------------------------------

def test_sine_eval_spans_full_range():
    src = SineTaskSource(K=4, tasks_per_agent=3, shots=5, n_domains=16,
                         seed=0)
    ev = src.eval_sample(200, seed=1)
    assert ev.support[0].shape == (200, 5, 1)
    # eval draws bands beyond any single agent's shard
    shard0 = set(src.sources()[0].domains.tolist())
    assert not set(ev.domains.tolist()) <= shard0


def test_fewshot_eval_uses_meta_test_classes():
    src = FewShotTaskSource(K=3, tasks_per_agent=2, n_classes=40, n_way=4,
                            k_shot=1, n_query=3, seed=0)
    ev = src.eval_sample(8, seed=2)
    test_classes = set(src.sampler._test_classes.tolist())
    assert set(ev.domains.reshape(-1).tolist()) <= test_classes


def test_lm_eval_uses_held_out_domains():
    src = LMTaskSource(vocab_size=256, seq_len=12, K=4, tasks_per_agent=2,
                       task_batch=3, n_domains=12, holdout_domains=2, seed=3)
    ev = src.eval_sample(16, seed=5, task_batch=4)
    assert ev.support["tokens"].shape == (16, 4, 12)
    assert set(ev.domains.tolist()) <= {10, 11}       # the held-out tail
    # no train shard ever contains a held-out domain
    for stream in src.sources():
        assert not set(stream.domains) & {10, 11}


def test_fewshot_source_rejects_shards_too_small_for_way():
    with pytest.raises(ValueError, match="too few"):
        FewShotTaskSource(K=8, n_classes=40, n_way=5, train_fraction=0.8)


# ---------------------------------------------------------------------------
# The recurring-vs-unseen split contract (Fallah et al. 2021): on every
# source, split='recurring' draws only trained domains, split='unseen' only
# held-out ones, and the two sets are disjoint.
# ---------------------------------------------------------------------------

def make_split_sources():
    return [
        SineTaskSource(K=4, tasks_per_agent=3, shots=5, n_domains=16,
                       holdout_domains=4, seed=3),
        FewShotTaskSource(K=3, tasks_per_agent=2, n_classes=40, n_way=4,
                          k_shot=1, n_query=3, seed=3),
        LMTaskSource(vocab_size=256, seq_len=12, K=4, tasks_per_agent=2,
                     task_batch=3, n_domains=12, holdout_domains=2, seed=3),
    ]


@pytest.mark.parametrize("source", make_split_sources(), ids=SOURCE_IDS)
def test_eval_splits_draw_disjoint_domain_sets(source):
    rec = source.eval_sample(64, seed=1, split="recurring")
    uns = source.eval_sample(64, seed=1, split="unseen")
    rec_doms = set(np.asarray(rec.domains).reshape(-1).tolist())
    uns_doms = set(np.asarray(uns.domains).reshape(-1).tolist())
    assert rec_doms and uns_doms
    assert not rec_doms & uns_doms, \
        f"recurring and unseen overlap: {rec_doms & uns_doms}"


@pytest.mark.parametrize("source", make_split_sources(), ids=SOURCE_IDS)
def test_recurring_is_trained_unseen_is_not(source):
    """'recurring' ⊆ the union of agent shards; 'unseen' touches none."""
    trained = set(np.concatenate(
        [s.domains for s in source.sources()]).tolist())
    rec = source.eval_sample(64, seed=2, split="recurring")
    uns = source.eval_sample(64, seed=2, split="unseen")
    assert set(np.asarray(rec.domains).reshape(-1).tolist()) <= trained
    assert not set(np.asarray(uns.domains).reshape(-1).tolist()) & trained


def test_sine_unseen_without_holdout_raises():
    src = SineTaskSource(K=4, n_domains=16, holdout_domains=0)
    with pytest.raises(ValueError, match="holdout_domains"):
        src.eval_sample(4, split="unseen")
    # legacy default (full range) and recurring still work
    assert src.eval_sample(4).domains.shape == (4,)
    assert src.eval_sample(4, split="recurring").domains.shape == (4,)


def test_unknown_split_rejected():
    src = SineTaskSource(K=4, n_domains=16, holdout_domains=4)
    with pytest.raises(ValueError, match="unknown eval split"):
        src.eval_sample(4, split="test")


def test_sine_holdout_excluded_from_training():
    src = SineTaskSource(K=4, tasks_per_agent=3, n_domains=16,
                         holdout_domains=4, seed=0)
    held_out = set(range(12, 16))
    for stream in src.sources():
        assert not set(stream.domains.tolist()) & held_out
    for step in range(4):
        drawn = set(np.asarray(src.sample(step).domains).reshape(-1).tolist())
        assert not drawn & held_out


# ---------------------------------------------------------------------------
# Vectorized LM generation matches the domain Markov structure
# ---------------------------------------------------------------------------

def test_lm_vectorized_respects_domain_tables():
    src = LMTaskSource(vocab_size=64, seq_len=10, K=2, tasks_per_agent=2,
                       task_batch=2, n_domains=4, seed=9)
    ep = src.sample(0)
    tables = src._tables()
    toks = np.concatenate([ep.support["tokens"], ep.query["tokens"]], axis=2)
    labs = np.concatenate([ep.support["labels"], ep.query["labels"]], axis=2)
    seqs = np.concatenate([toks, labs[..., -1:]], axis=-1)  # full chains
    for k in range(2):
        for t in range(2):
            dom = int(ep.domains[k, t])
            allowed = tables[dom]                     # (buckets, branching)
            for row in seqs[k, t]:
                for a, b in zip(row[:-1], row[1:]):
                    assert b in allowed[a % src.n_buckets]


# ---------------------------------------------------------------------------
# Flat-batch layout: Episode.as_flat_batch is split_meta_batch's inverse
# ---------------------------------------------------------------------------

def test_as_flat_batch_roundtrips_through_split_meta_batch():
    from repro.configs import get_config
    from repro.launch import steps as S
    src = LMTaskSource(vocab_size=64, seq_len=6, K=2, tasks_per_agent=2,
                       task_batch=2, n_domains=8, seed=0)
    ep = src.sample(3)
    flat = ep.as_flat_batch()
    assert flat["tokens"].shape == (2 * 2 * 2 * 2, 6)
    sup, qry = S.split_meta_batch(get_config("qwen2-1.5b"), flat,
                                  K=2, T=2, tb=2)
    np.testing.assert_array_equal(np.asarray(sup["tokens"]),
                                  ep.support["tokens"])
    np.testing.assert_array_equal(np.asarray(qry["labels"]),
                                  ep.query["labels"])


# ---------------------------------------------------------------------------
# Regression: the production trainer's source is heterogeneous (the old
# make_batch path fed every agent the same single domain per step)
# ---------------------------------------------------------------------------

def test_train_source_gives_agents_disjoint_heterogeneous_domains():
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.launch import steps as S
    from repro.launch.train import make_train_source
    cfg = get_config("qwen2-1.5b").reduced()
    shape = InputShape("het_test", 16, 16, "train")
    K = 4
    T, tb = S.batch_geometry(cfg, shape, K)
    source = make_train_source(cfg, shape, K, T, tb)
    streams = source.sources()
    for i in range(K):
        for j in range(i + 1, K):
            assert not set(streams[i].domains) & set(streams[j].domains)
    # across steps, the union of drawn domains spans >1 domain and each
    # agent stays inside its own shard — make_batch (one domain for the
    # whole global batch, identical for all agents) fails both
    drawn = [set() for _ in range(K)]
    for step in range(8):
        ep = source.sample(step)
        for k in range(K):
            drawn[k] |= set(np.asarray(ep.domains[k]).tolist())
    for i in range(K):
        for j in range(i + 1, K):
            assert not drawn[i] & drawn[j]
    assert sum(len(d) for d in drawn) > 1
