"""Serving correctness: incremental decode against the KV cache must equal
the full-sequence forward, for every architecture family (MoE with no-drop
capacity — capacity drops are the only documented train/serve divergence)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.transformer import build_model

ARCHS = list_archs()


def _cfg(arch):
    cfg = get_config(arch).reduced()
    kw = dict(attn_q_chunk=8, dtype="float32")
    if cfg.num_experts:
        kw["moe_capacity_factor"] = float(cfg.num_experts)   # no drops
    return dataclasses.replace(cfg, **kw)


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_matches_forward(arch):
    cfg = _cfg(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    enc = None
    if cfg.arch_type == "audio":
        batch["encoder_frames"] = jax.random.normal(
            jax.random.key(1), (B, cfg.encoder_frames, cfg.d_model)) * 0.1
        enc = m.encode(params, batch["encoder_frames"])
    if cfg.arch_type == "vlm":
        batch["image_patches"] = jax.random.normal(
            jax.random.key(1), (B, cfg.num_patches, cfg.d_model)) * 0.1
        enc = batch["image_patches"] @ params["vision_proj"]
    full = m.forward(params, batch)
    cache = m.init_cache(B, S, jnp.float32, params=params, enc=enc)
    step = jax.jit(m.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, toks[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               atol=5e-4, rtol=1e-3)


def test_sliding_window_ring_buffer():
    """With cache length == window < seq, decode must equal the full
    forward under the same sliding-window mask (ring-buffer indexing)."""
    cfg = _cfg("mixtral-8x22b")
    cfg = dataclasses.replace(cfg, sliding_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full = m.forward(params, {"tokens": toks, "labels": toks})
    cache = m.init_cache(B, S, jnp.float32)        # allocates window-sized kv
    outs = []
    for t in range(S):
        logits, cache = m.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.full((B,), t, jnp.int32))
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full),
                               atol=5e-4, rtol=1e-3)


def test_mla_cache_is_latent_sized():
    cfg = _cfg("deepseek-v2-lite-16b")
    m = build_model(cfg)
    cache = m.init_cache(2, 16, jnp.float32)
    leaves = jax.tree.leaves(cache)
    # MLA layers cache (B, S, rank) + (B, S, rope) — never (B, S, H, dn+dv)
    per_token = sum(l.shape[-1] for l in leaves if l.ndim == 3)
    assert per_token <= 2 * (cfg.kv_lora_rank + cfg.qk_rope_dim)


def test_mamba_cache_is_constant_in_seq():
    cfg = _cfg("mamba2-130m")
    m = build_model(cfg)
    c1 = m.init_cache(2, 16, jnp.float32)
    c2 = m.init_cache(2, 512, jnp.float32)
    s1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c1))
    s2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c2))
    assert s1 == s2   # O(1) state — the long_500k eligibility property
