"""Sharding-rule logic on AbstractMesh (no real devices needed)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh, mesh_axis_sizes
from repro.configs import get_config
from repro.models.init import axes_tree, with_agent_axis
from repro.models.transformer import build_model
from repro.sharding.rules import rules_for, spec_for, tree_shardings

MESH1 = abstract_mesh((16, 16), ("data", "model"))
MESH2 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_agent_dim_data_placement():
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, MESH1, "train")
    s = spec_for(("agent", "vocab", "embed"), (16, 152064, 3584), r, MESH1)
    assert s == P("data", "model", None)


def test_agent_dim_multi_pod_spans_both():
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, MESH2, "train")
    s = spec_for(("agent", "embed", "ffn"), (32, 3584, 18944), r, MESH2)
    assert s == P(("pod", "data"), None, "model")


def test_pod_placement_fsdp():
    cfg = get_config("mixtral-8x22b")
    r = rules_for(cfg, MESH2, "train")
    # experts: 8 ∤ 16 on data — falls through to model? 8 ∤ 16 there too →
    # replicated; ffn takes model; embed takes FSDP data
    s = spec_for(("agent", "experts", "embed", "ffn"),
                 (2, 8, 6144, 16384), r, MESH2)
    assert s == P("pod", None, "data", "model")


def test_jamba_experts_shard_over_data():
    cfg = get_config("jamba-1.5-large-398b")
    r = rules_for(cfg, MESH2, "train")
    s = spec_for(("agent", "experts", "embed", "ffn"),
                 (2, 16, 8192, 24576), r, MESH2)
    assert s == P("pod", "data", None, "model")   # experts win the data axis


def test_indivisible_heads_stay_replicated():
    cfg = get_config("qwen2-1.5b")                # 12 heads, attn_shard=none
    r = rules_for(cfg, MESH1, "train")
    s = spec_for(("embed", "heads", "head_dim"), (1536, 12, 128), r, MESH1)
    assert s == P(None, None, None)


def test_whisper_attention_replicated_in_train():
    # HC3: head_dim TP all-reduced the (S,T) logits per layer — whisper
    # trains with attention replicated across the model axis
    cfg = get_config("whisper-large-v3")          # attn_shard=none
    r = rules_for(cfg, MESH1, "train")
    s = spec_for(("embed", "heads", "head_dim"), (1280, 20, 64), r, MESH1)
    assert s == P(None, None, None)
    # head_dim sharding remains selectable as an override
    import dataclasses
    cfg_hd = dataclasses.replace(cfg, attn_shard="head_dim")
    r2 = rules_for(cfg_hd, MESH1, "train")
    s2 = spec_for(("embed", "heads", "head_dim"), (1280, 20, 64), r2, MESH1)
    assert s2 == P(None, None, "model")


def test_decode_always_shards_head_dim():
    # the KV cache must never replicate across the model axis at serving
    cfg = get_config("whisper-large-v3")          # attn_shard=none
    r = rules_for(cfg, MESH1, "decode")
    s = spec_for(("batch", "seq", "kv_heads", "head_dim"),
                 (128, 32768, 20, 64), r, MESH1)
    assert s == P("data", None, None, "model")


def test_decode_cache_long_context_seq_sharding():
    cfg = get_config("jamba-1.5-large-398b")
    r = rules_for(cfg, MESH1, "decode")
    # batch=1 cannot shard → seq dim takes the data axis
    s = spec_for(("batch", "seq", "kv_heads", "head_dim"),
                 (1, 524288, 8, 128), r, MESH1)
    assert s == P(None, "data", None, "model")


def test_decode_batch_sharding_when_divisible():
    cfg = get_config("command-r-35b")
    r = rules_for(cfg, MESH1, "decode")
    s = spec_for(("batch", "seq", "kv_heads", "head_dim"),
                 (128, 32768, 8, 128), r, MESH1)
    assert s == P("data", None, None, "model")


def test_no_mesh_axis_used_twice_per_leaf():
    cfg = get_config("command-r-35b")
    r = rules_for(cfg, MESH1, "train")
    for axes, shape in [
        (("agent", "vocab", "embed"), (16, 256000, 8192)),
        (("agent", "embed", "heads", "head_dim"), (16, 8192, 64, 128)),
    ]:
        s = spec_for(axes, shape, r, MESH1)
        used = [a for part in s for a in
                ((part,) if isinstance(part, str) else (part or ()))]
        assert len(used) == len(set(used))


def test_every_arch_every_param_gets_valid_spec():
    """Full sweep: every parameter of every assigned arch receives a spec
    whose mesh-axis sizes divide the corresponding dims, on both meshes."""
    from repro.configs import list_archs
    for arch in list_archs():
        cfg = get_config(arch)
        model = build_model(cfg)
        specs = with_agent_axis(model.specs(), 16)
        axes = axes_tree(specs)
        for mesh in (MESH1, MESH2):
            sizes = mesh_axis_sizes(mesh)
            r = rules_for(cfg, mesh, "train")
            flat_axes = jax.tree.leaves(
                axes, is_leaf=lambda x: isinstance(x, tuple)
                and all(isinstance(a, (str, type(None))) for a in x))
            flat_specs = jax.tree.leaves(
                specs, is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "axes"))
            for ax, sp in zip(flat_axes, flat_specs):
                pspec = spec_for(ax, sp.shape, r, mesh)
                for dim, assignment in zip(sp.shape, tuple(pspec) + (None,) * 8):
                    if assignment is None:
                        continue
                    parts = (assignment,) if isinstance(assignment, str) \
                        else assignment
                    total = 1
                    for a in parts:
                        total *= sizes[a]
                    assert dim % total == 0, (arch, sp.shape, pspec)


# ---- spec_for mechanics (direct coverage) ----------------------------------

AMESH2 = abstract_mesh((16, 16), ("agent", "model"))
AMESH3 = abstract_mesh((8, 2, 16), ("agent", "data", "model"))


def test_spec_for_joint_candidate_32_way():
    # a joint ('pod','data') candidate shards one dim over both axes (32-way)
    cfg = get_config("qwen2-7b")                  # placement=data
    r = rules_for(cfg, MESH2, "train")
    assert spec_for(("agent",), (32,), r, MESH2) == P(("pod", "data"))
    # a dim the joint extent does not divide stays replicated: the joint
    # candidate is all-or-nothing, there is no partial fallback to 'data'
    assert spec_for(("agent",), (48,), r, MESH2) == P(None)


def test_spec_for_non_dividing_falls_to_next_candidate():
    # batch candidates on a 3D agent mesh: ('agent','data') then ('agent',)
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, AMESH3, "train")
    assert spec_for(("batch", None), (256, 64), r, AMESH3) == \
        P(("agent", "data"), None)
    # 8 % (8·2) != 0 → falls through to the ('agent',) candidate
    assert spec_for(("batch", None), (8, 64), r, AMESH3) == P("agent", None)


def test_spec_for_zero_size_dim_replicated():
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, MESH1, "train")
    # 0 % anything == 0 arithmetically, but an empty dim must never be
    # assigned a mesh axis (XLA rejects sharding a zero extent)
    assert spec_for(("ffn",), (0,), r, MESH1) == P(None)
    assert spec_for(("agent", "ffn"), (16, 0), r, MESH1) == P("data", None)


def test_spec_for_used_axis_conflict():
    # vocab outranks ffn in priority; both want 'model' — the second dim
    # must fall through to replicated, not reuse the axis
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, MESH1, "train")
    s = spec_for(("vocab", "ffn"), (152064, 18944), r, MESH1)
    assert s == P("model", None)


def test_agent_axis_rules_2d():
    # first-class agent axis: logical 'agent' → mesh 'agent', TP unchanged
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, AMESH2, "train")
    s = spec_for(("agent", "vocab", "embed"), (16, 152064, 3584), r, AMESH2)
    assert s == P("agent", "model", None)
    # no 'data' on the 2D mesh → no FSDP; embed stays replicated
    s = spec_for(("agent", "embed", "ffn"), (16, 3584, 18944), r, AMESH2)
    assert s == P("agent", None, "model")


def test_agent_axis_makes_placement_moot():
    import dataclasses
    for placement in ("data", "pod"):
        cfg = dataclasses.replace(get_config("qwen2-7b"),
                                  placement=placement)
        r = rules_for(cfg, AMESH2, "train")
        s = spec_for(("agent", "embed", "ffn"), (16, 3584, 18944), r, AMESH2)
        assert s == P("agent", None, "model"), placement


def test_agent_axis_3d_intra_agent_fsdp():
    # (agent, data, model): 'data' is pure intra-agent FSDP/batch; embed
    # gets the data axis, batch shards jointly over (agent, data)
    cfg = get_config("qwen2-7b")
    r = rules_for(cfg, AMESH3, "train")
    s = spec_for(("agent", "embed", "ffn"), (8, 3584, 18944), r, AMESH3)
    assert s == P("agent", "data", "model")
