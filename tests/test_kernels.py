"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology
from repro.kernels.dif_combine.dif_combine import dif_combine
from repro.kernels.dif_combine.ops import combine_tree
from repro.kernels.dif_combine.ref import dif_combine_ref
from repro.kernels.flash_attention.ops import flash_attention, gqa_flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# dif_combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [2, 6, 8, 16])
@pytest.mark.parametrize("M,bm", [(512, 128), (2048, 512), (1024, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dif_combine_sweep(K, M, bm, dtype):
    A = jnp.asarray(topology.combination_matrix(K, "ring"), dtype)
    phi = jax.random.normal(jax.random.key(K * M), (K, M), jnp.float32).astype(dtype)
    out = dif_combine(A, phi, block_m=bm, interpret=True)
    ref = dif_combine_ref(A, phi)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_dif_combine_tree_pads_ragged_leaves():
    K = 4
    A = jnp.asarray(topology.combination_matrix(K, "full"), jnp.float32)
    phi = {"a": jax.random.normal(jax.random.key(0), (K, 3, 37)),
           "b": jax.random.normal(jax.random.key(1), (K, 130))}
    out = combine_tree(A, phi, block_m=128, interpret=True)
    for name in phi:
        flat = phi[name].reshape(K, -1)
        ref = dif_combine_ref(A, flat).reshape(phi[name].shape)
        np.testing.assert_allclose(out[name], ref, atol=1e-5)


def test_dif_combine_doubly_stochastic_preserves_mean():
    K, M = 8, 512
    A = jnp.asarray(topology.combination_matrix(K, "erdos"), jnp.float32)
    phi = jax.random.normal(jax.random.key(3), (K, M))
    out = dif_combine(A, phi, block_m=128, interpret=True)
    np.testing.assert_allclose(out.mean(0), phi.mean(0), atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,bq,bk", [(128, 128, 128), (256, 64, 128),
                                     (512, 128, 64)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, bq, bk, causal, dtype):
    B, H, d = 2, 3, 64
    q, k, v = [jax.random.normal(jax.random.key(i), (B, H, S, d),
                                 jnp.float32).astype(dtype) for i in range(3)]
    out = flash_attention(q, k, v, causal, None, bq, bk, True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 100])
def test_flash_attention_sliding_window(window):
    B, H, S, d = 1, 2, 256, 32
    q, k, v = [jax.random.normal(jax.random.key(i), (B, H, S, d))
               for i in range(3)]
    out = flash_attention(q, k, v, True, window, 64, 64, True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


def test_flash_attention_grad_matches_ref():
    B, H, S, d = 1, 2, 128, 32
    q, k, v = [jax.random.normal(jax.random.key(i), (B, H, S, d))
               for i in range(3)]

    def f_kernel(q, k, v):
        return (flash_attention(q, k, v, True, None, 64, 64, True) ** 2).sum()

    def f_ref(q, k, v):
        return (attention_ref(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-5)


def test_gqa_wrapper_expands_kv():
    B, S, H, KV, d = 2, 128, 8, 2, 32
    q = jax.random.normal(jax.random.key(0), (B, S, H, d))
    k = jax.random.normal(jax.random.key(1), (B, S, KV, d))
    v = jax.random.normal(jax.random.key(2), (B, S, KV, d))
    out = gqa_flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    kk = jnp.repeat(k, H // KV, axis=2).swapaxes(1, 2)
    vv = jnp.repeat(v, H // KV, axis=2).swapaxes(1, 2)
    ref = attention_ref(q.swapaxes(1, 2), kk, vv, causal=True).swapaxes(1, 2)
    np.testing.assert_allclose(out, ref, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("L,chunk", [(128, 32), (256, 64), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(L, chunk, dtype):
    B, H, P, N = 2, 2, 16, 32
    ks = jax.random.split(jax.random.key(L + chunk), 5)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, H, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, L, H, N)) * 0.3
    y, s = ssd_scan_pallas(x.astype(dtype), dt.astype(dtype), A,
                           Bm.astype(dtype), Cm.astype(dtype),
                           chunk=chunk, interpret=True)
    yr, sr = ssd_scan_ref(x, dt, A, Bm, Cm)
    tol = 3e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(y, np.float32), yr, atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s, np.float32), sr,
                               atol=tol, rtol=tol)


def test_ssd_scan_state_continuity():
    """Scanning two halves with carried state == one full scan."""
    B, L, H, P, N = 1, 128, 1, 8, 16
    ks = jax.random.split(jax.random.key(9), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.5
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, H, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, L, H, N)) * 0.3
    _, s_full = ssd_scan_ref(x, dt, A, Bm, Cm)
    _, s_k = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    np.testing.assert_allclose(s_k, s_full, atol=1e-4)


# ---------------------------------------------------------------------------
# fused flash attention (Pallas forward + Pallas backward)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 96)])
@pytest.mark.parametrize("S,bq,bk", [(128, 64, 64), (256, 128, 64)])
def test_flash_fused_backward_matches_ref(causal, window, S, bq, bk):
    from repro.kernels.flash_attention.ops import flash_attention_fused
    B, H, d = 1, 2, 32
    q, k, v = [jax.random.normal(jax.random.key(i), (B, H, S, d))
               for i in range(3)]

    def f(q, k, v):
        return (flash_attention_fused(q, k, v, causal, window, bq, bk, True)
                ** 2).sum()

    def fr(q, k, v):
        return (attention_ref(q, k, v, causal=causal, window=window) ** 2).sum()

    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-5)


def test_flash_fwd_lse_matches_logsumexp():
    from repro.kernels.flash_attention.flash_attention import flash_attention_fwd_lse
    B, H, S, d = 1, 1, 128, 16
    q, k, v = [jax.random.normal(jax.random.key(i), (B, H, S, d))
               for i in range(3)]
    _, lse = flash_attention_fwd_lse(q, k, v, causal=True, block_q=64,
                                     block_k=64, interpret=True)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) / np.sqrt(d)
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask, logits, -1e30)
    ref = jax.scipy.special.logsumexp(logits, axis=-1)
    np.testing.assert_allclose(lse[..., 0], ref, atol=1e-4)


# ---------------------------------------------------------------------------
# Error paths: misaligned shapes must fail loudly, with the numbers
# ---------------------------------------------------------------------------


def test_flash_fwd_rejects_misaligned_seq_with_numbers():
    from repro.kernels.flash_attention.flash_attention import \
        flash_attention_fwd_lse
    q = k = v = jnp.zeros((1, 1, 130, 16), jnp.float32)
    with pytest.raises(ValueError, match=r"seq_q=130 % block_q=128 = 2"):
        flash_attention_fwd_lse(q, k, v, interpret=True)


def test_flash_bwd_rejects_misaligned_seq_with_numbers():
    from repro.kernels.flash_attention.flash_bwd import flash_attention_bwd
    q = k = v = out = do = jnp.zeros((1, 1, 130, 16), jnp.float32)
    lse = jnp.zeros((1, 1, 130), jnp.float32)
    with pytest.raises(ValueError, match=r"seq_k=130 % block_k=128 = 2"):
        flash_attention_bwd(q, k, v, out, lse, do, interpret=True)


def test_ssd_scan_rejects_misaligned_length_with_numbers():
    B, L, HH, P, N = 1, 100, 1, 4, 8
    x = jnp.zeros((B, L, HH, P), jnp.float32)
    dt = jnp.zeros((B, L, HH), jnp.float32)
    A = jnp.zeros((HH,), jnp.float32)
    Bm = Cm = jnp.zeros((B, L, HH, N), jnp.float32)
    with pytest.raises(ValueError, match=r"L=100 % chunk=128 = 100"):
        ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=128, interpret=True)
