"""MoE routing invariants (seeded parametrize grids; no optional deps)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.init import materialize


def _moe_cfg(E=4, k=2, cap=8.0):
    cfg = get_config("mixtral-8x22b").reduced()
    return dataclasses.replace(cfg, num_experts=E, experts_per_token=k,
                               moe_capacity_factor=cap, d_model=32, moe_d_ff=16,
                               d_ff=16)


@pytest.mark.parametrize("seed", [0, 13, 30])
@pytest.mark.parametrize("E", [2, 4, 8])
@pytest.mark.parametrize("k", [1, 2])
def test_moe_output_finite_and_shaped(seed, E, k):
    cfg = _moe_cfg(E=E, k=k)
    params = materialize(L.moe_specs(cfg), jax.random.key(seed))
    x = jax.random.normal(jax.random.key(seed + 1), (2, 8, cfg.d_model))
    y = L.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_route_group_each_token_gets_k_slots_when_capacity_ample():
    G, E, k, C = 16, 4, 2, 16
    logits = jax.random.normal(jax.random.key(0), (G, E))
    buf_tok, buf_w = L._route_group(logits, k, E, C)
    counts = np.bincount(np.asarray(buf_tok)[np.asarray(buf_w) > 0],
                         minlength=G + 1)
    assert np.all(counts[:G] == k)          # every token routed k times
    # combine weights per token sum to 1 (renormalized top-k softmax)
    w_per_tok = np.zeros(G + 1)
    np.add.at(w_per_tok, np.asarray(buf_tok), np.asarray(buf_w))
    np.testing.assert_allclose(w_per_tok[:G], 1.0, atol=1e-5)


def test_route_group_respects_capacity():
    G, E, k, C = 32, 2, 1, 4
    # force every token to expert 0
    logits = jnp.stack([jnp.ones(G) * 10, jnp.zeros(G)], axis=1)
    buf_tok, buf_w = L._route_group(logits, k, E, C)
    kept = np.asarray(buf_w) > 0
    assert kept.sum() == C                   # overflow dropped
    assert np.all(np.asarray(buf_tok)[: C][kept[:C]] < G)


def test_moe_zero_capacity_drop_changes_output():
    cfg_tight = _moe_cfg(E=4, k=2, cap=0.3)
    cfg_ample = dataclasses.replace(cfg_tight, moe_capacity_factor=8.0)
    params = materialize(L.moe_specs(cfg_ample), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 32, cfg_ample.d_model))
    y_t = L.moe_apply(params, cfg_tight, x)
    y_a = L.moe_apply(params, cfg_ample, x)
    assert float(jnp.max(jnp.abs(y_t - y_a))) > 1e-6


def test_shared_experts_added():
    cfg = dataclasses.replace(_moe_cfg(), num_shared_experts=1)
    params = materialize(L.moe_specs(cfg), jax.random.key(0))
    assert "shared" in params
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    y = L.moe_apply(params, cfg, x)
    y_no_shared = L.moe_apply(
        {k: v for k, v in params.items() if k != "shared"},
        dataclasses.replace(cfg, num_shared_experts=0), x)
    shared_part = L.mlp_apply(params["shared"], x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(y_no_shared + shared_part),
                               atol=1e-5)


def test_moe_grad_flows_to_router_and_experts():
    cfg = _moe_cfg()
    params = materialize(L.moe_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))
    g = jax.grad(lambda p: jnp.sum(L.moe_apply(p, cfg, x) ** 2))(params)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w1"]))) > 0


def test_load_balance_loss_properties():
    """Switch aux loss: == 1 at uniform routing, > 1 when skewed, and its
    gradient pushes the router toward balance."""
    import jax
    import jax.numpy as jnp
    from repro.models.layers import moe_load_balance_loss
    cfg = _moe_cfg(E=4, k=1)
    d, E = cfg.d_model, 4
    x = jax.random.normal(jax.random.key(0), (2, 32, d))
    # uniform router (zero weights): probs uniform -> loss == 1
    p_uniform = {"router": jnp.zeros((d, E))}
    l_u = float(moe_load_balance_loss(p_uniform, cfg, x))
    assert abs(l_u - 1.0) < 0.15   # f is argmax-tie-resolved, p exact 1/E
    # skewed router: all tokens to expert 0 -> loss approaches E
    # (positive inputs so the logit for expert 0 is large for EVERY token)
    x_pos = jnp.abs(x)
    w = jnp.zeros((d, E)).at[:, 0].set(1.0)
    l_s = float(moe_load_balance_loss({"router": w * 50}, cfg, x_pos))
    assert l_s > 2.0
    # gradient exists and is finite
    g = jax.grad(lambda p: moe_load_balance_loss(p, cfg, x))({"router": w})
    assert bool(jnp.all(jnp.isfinite(g["router"])))
