"""Production (shard_map + ppermute) sparse combine == dense combine.

Runs in a subprocess with 8 forced host devices (the main test process owns
a single-device jax runtime)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"   # never probe accelerator plugins
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import diffusion, topology

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    K = 4
    A = topology.combination_matrix(K, "ring")
    phi = {
        "w": jax.random.normal(jax.random.key(0), (K, 8, 6)),
        "b": jax.random.normal(jax.random.key(1), (K, 10)),
    }
    with mesh:
        phi_sh = {
            "w": jax.device_put(phi["w"], NamedSharding(mesh, P("data", None, "model"))),
            "b": jax.device_put(phi["b"], NamedSharding(mesh, P("data", None))),
        }
        specs = {"w": P("data", None, "model"), "b": P("data", None)}
        sparse = diffusion.make_mesh_sparse_combine(A, mesh, "data",
                                                    in_specs=specs)
        out = jax.jit(sparse)(phi_sh)
        ref = diffusion.dense_combine(jnp.asarray(A), phi)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

        # dynamic schedule: the shard_mapped ppermute rounds with
        # step-gathered weights match the dense stacked einsum at every step
        topo = topology.build_topology("ring", K)
        sched = topology.make_schedule("link_failure", topo, p=0.3,
                                       period=5, seed=1)
        dyn = jax.jit(diffusion.make_combine(
            "mesh_sparse_dynamic", A=sched.matrices, mesh=mesh,
            axis_name="data", in_specs=specs))
        for step in [0, 3, 7]:
            out = dyn(phi_sh, jnp.int32(step))
            ref = diffusion.dense_combine(
                jnp.asarray(sched.matrix_at(step)), phi)
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
    print("SPARSE_MESH_OK")
""")


def test_mesh_sparse_combine_equals_dense():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=300)
    assert "SPARSE_MESH_OK" in out.stdout, out.stderr[-2000:]


SCRIPT_2D = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import diffusion, topology
    from repro.launch.mesh import make_host_mesh

    K = 4
    A = topology.combination_matrix(K, "ring")
    phi = {
        "w": jax.random.normal(jax.random.key(0), (K, 8, 6)),
        "b": jax.random.normal(jax.random.key(1), (K, 10)),
    }
    ref = diffusion.dense_combine(jnp.asarray(A), phi)

    # --- 2D (agent, model) mesh: TP-sharded leaves ride the permute ------
    mesh2d = make_host_mesh(model=2, agents=K)
    assert mesh2d.axis_names == ("agent", "model"), mesh2d.axis_names
    specs = {"w": P("agent", None, "model"), "b": P("agent", None)}
    with mesh2d:
        phi_sh = {
            k: jax.device_put(v, NamedSharding(mesh2d, specs[k]))
            for k, v in phi.items()
        }
        # select_backend must detect the agent axis on its own: a ring on
        # a 2D (agent, model) mesh routes to the shard_mapped backend
        # without the caller passing axis_name
        assert diffusion.select_backend(A, mesh=mesh2d) == "mesh_sparse"
        sparse = diffusion.make_combine("mesh_sparse", A=A, mesh=mesh2d,
                                        axis_name="agent", in_specs=specs)
        out2d = jax.jit(sparse)(phi_sh)
        for a, b in zip(jax.tree.leaves(out2d), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)

        topo = topology.build_topology("ring", K)
        sched = topology.make_schedule("link_failure", topo, p=0.3,
                                       period=5, seed=1)
        dyn = jax.jit(diffusion.make_combine(
            "mesh_sparse_dynamic", A=sched.matrices, mesh=mesh2d,
            axis_name="agent", in_specs=specs))
        for step in [0, 3, 7]:
            outd = dyn(phi_sh, jnp.int32(step))
            refd = diffusion.dense_combine(
                jnp.asarray(sched.matrix_at(step)), phi)
            for a, b in zip(jax.tree.leaves(outd), jax.tree.leaves(refd)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)

    # --- 1D-vs-2D bit-identity: adding the model axis must not change ----
    # the combine math (same ppermute rounds, same per-element reduction
    # order; TP only splits the trailing dim's storage)
    mesh1d = compat.make_mesh((K,), ("agent",))
    specs1d = {"w": P("agent"), "b": P("agent")}
    with mesh1d:
        phi_1d = {
            k: jax.device_put(v, NamedSharding(mesh1d, specs1d[k]))
            for k, v in phi.items()
        }
        sparse1d = diffusion.make_combine("mesh_sparse", A=A, mesh=mesh1d,
                                          axis_name="agent",
                                          in_specs=specs1d)
        out1d = jax.jit(sparse1d)(phi_1d)
    for a, b in zip(jax.tree.leaves(out1d), jax.tree.leaves(out2d)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("SPARSE_MESH_2D_OK")
""")


def test_mesh_sparse_combine_2d_agent_mesh():
    """Agent-axis 2D mesh: parity with dense + bit-identity with the 1D
    agent-only mesh (the TP axis must be transparent to the combine)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT_2D],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=300)
    assert "SPARSE_MESH_2D_OK" in out.stdout, out.stderr[-2000:]
