"""Production (shard_map + ppermute) sparse combine == dense combine.

Runs in a subprocess with 8 forced host devices (the main test process owns
a single-device jax runtime)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"   # never probe accelerator plugins
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import diffusion, topology

    mesh = compat.make_mesh((4, 2), ("data", "model"))
    K = 4
    A = topology.combination_matrix(K, "ring")
    phi = {
        "w": jax.random.normal(jax.random.key(0), (K, 8, 6)),
        "b": jax.random.normal(jax.random.key(1), (K, 10)),
    }
    with mesh:
        phi_sh = {
            "w": jax.device_put(phi["w"], NamedSharding(mesh, P("data", None, "model"))),
            "b": jax.device_put(phi["b"], NamedSharding(mesh, P("data", None))),
        }
        specs = {"w": P("data", None, "model"), "b": P("data", None)}
        sparse = diffusion.make_mesh_sparse_combine(A, mesh, "data",
                                                    in_specs=specs)
        out = jax.jit(sparse)(phi_sh)
        ref = diffusion.dense_combine(jnp.asarray(A), phi)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

        # dynamic schedule: the shard_mapped ppermute rounds with
        # step-gathered weights match the dense stacked einsum at every step
        topo = topology.build_topology("ring", K)
        sched = topology.make_schedule("link_failure", topo, p=0.3,
                                       period=5, seed=1)
        dyn = jax.jit(diffusion.make_combine(
            "mesh_sparse_dynamic", A=sched.matrices, mesh=mesh,
            axis_name="data", in_specs=specs))
        for step in [0, 3, 7]:
            out = dyn(phi_sh, jnp.int32(step))
            ref = diffusion.dense_combine(
                jnp.asarray(sched.matrix_at(step)), phi)
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
    print("SPARSE_MESH_OK")
""")


def test_mesh_sparse_combine_equals_dense():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=300)
    assert "SPARSE_MESH_OK" in out.stdout, out.stderr[-2000:]
