"""Unit checks for the roofline model's meta-step compute multipliers."""
import os
import sys
import types

import pytest

# benchmarks/ is a script directory at the repo root (no package install);
# conftest only puts src/ on the path.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.roofline import expected_meta_multiplier


def _cfg(meta_mode):
    return types.SimpleNamespace(meta_mode=meta_mode)


def test_meta_multipliers_per_mode():
    assert expected_meta_multiplier(_cfg("maml")) == 2.5
    assert expected_meta_multiplier(_cfg("fomaml")) == 1.2
    # reptile has no outer backward — its outer 'gradient' is the adapted
    # parameter delta, so a meta step costs LESS than a plain train step
    assert expected_meta_multiplier(_cfg("reptile")) == 0.8


def test_reptile_is_cheaper_than_first_order_and_plain():
    rep = expected_meta_multiplier(_cfg("reptile"))
    assert rep < expected_meta_multiplier(_cfg("fomaml"))
    assert rep < 1.0 < expected_meta_multiplier(_cfg("maml"))


def test_unknown_mode_falls_back_to_first_order():
    assert expected_meta_multiplier(_cfg("anil")) == 1.2
