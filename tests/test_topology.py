"""Combination-matrix properties (paper Assumption 6 + Thm 1 quantities).

Former hypothesis property tests run as seeded parametrize grids so tier-1
collects with no optional dependencies.
"""
import numpy as np
import pytest

from repro.core import topology as T

TOPOS = ["ring", "full", "star", "grid", "torus", "erdos", "paper"]


@pytest.mark.parametrize("topo", TOPOS)
@pytest.mark.parametrize("K", [2, 4, 6, 9, 16])
def test_metropolis_doubly_stochastic_and_primitive(topo, K):
    if topo == "paper" and K != 6:
        pytest.skip("paper graph is K=6")
    A = T.combination_matrix(K, topo)
    assert T.is_doubly_stochastic(A)
    assert T.is_primitive(A)


@pytest.mark.parametrize("K", [3, 8, 16])
def test_uniform_rule_doubly_stochastic(K):
    A = T.combination_matrix(K, "ring", rule="uniform")
    assert T.is_doubly_stochastic(A)


@pytest.mark.parametrize("K", [3, 5, 11, 24])
@pytest.mark.parametrize("seed", [0, 17, 50])
def test_erdos_connected_and_mixing(K, seed):
    A = T.combination_matrix(K, "erdos", seed=seed)
    assert T.is_doubly_stochastic(A)
    lam2 = T.mixing_rate(A)
    assert 0.0 <= lam2 < 1.0  # connected + primitive => strict contraction


def test_mixing_rate_orders_topologies():
    """Denser graphs mix faster: λ₂(full) < λ₂(ring) for the same K."""
    K = 12
    lam_full = T.mixing_rate(T.combination_matrix(K, "full"))
    lam_ring = T.mixing_rate(T.combination_matrix(K, "ring"))
    assert lam_full < lam_ring < 1.0


def test_full_graph_metropolis_is_uniform_average():
    K = 5
    A = T.combination_matrix(K, "full")
    assert np.allclose(A, np.ones((K, K)) / K)
    assert T.mixing_rate(A) < 1e-8


def test_paper_graph_shape():
    A = T.combination_matrix(6, "paper")
    assert A.shape == (6, 6)
    assert T.is_doubly_stochastic(A)
    # 8 undirected edges -> 16 off-diagonal nonzeros
    assert (A > 0).sum() - (np.diagonal(A) > 0).sum() == 16


def test_permute_offsets_ring():
    K = 8
    A = T.combination_matrix(K, "ring")
    offs = T.permute_offsets(A, K)
    assert sorted(offs) == [1, K - 1]
    assert T.is_circulant(A)


def test_star_not_circulant():
    A = T.combination_matrix(6, "star")
    assert not T.is_circulant(A)


@pytest.mark.parametrize("K", [2, 3, 4, 6, 8, 12, 16])
def test_contraction_bound(K):
    """‖(Aᵀ − 11ᵀ/K) x‖ ≤ λ₂ ‖x‖ for mean-zero x (Thm 1 mechanism)."""
    A = T.combination_matrix(K, "ring")
    lam2 = T.mixing_rate(A)
    rng = np.random.default_rng(K)
    x = rng.normal(size=(K, 5))
    x -= x.mean(axis=0, keepdims=True)
    y = A.T @ x
    assert np.linalg.norm(y) <= lam2 * np.linalg.norm(x) + 1e-9


# ---------------------------------------------------------------------------
# Full TOPOLOGIES × rules invariant grid (Assumption 6 for every entry)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", sorted(T.TOPOLOGIES))
@pytest.mark.parametrize("rule", ["metropolis", "uniform"])
def test_every_topology_rule_satisfies_assumption6(topo, rule):
    K = T.FIXED_SIZE.get(topo, 12)
    A = T.combination_matrix(K, topo, rule=rule)
    assert T.is_doubly_stochastic(A)
    assert T.is_primitive(A)
    t = T.build_topology(topo, K, rule)
    assert t.connected
    assert 0.0 <= t.mixing_rate < 1.0
    np.testing.assert_allclose(t.matrix, A)
    d = t.diagnostics()
    assert d["doubly_stochastic"] and d["primitive"] and d["connected"]


def test_erdos_deterministic_for_fixed_seed():
    a = T.combination_matrix(24, "erdos", seed=7)
    b = T.combination_matrix(24, "erdos", seed=7)
    np.testing.assert_array_equal(a, b)
    c = T.combination_matrix(24, "erdos", seed=8)
    assert not np.array_equal(a, c)


def test_fixed_size_topology_rejects_mismatched_agents():
    with pytest.raises(ValueError) as ei:
        T.combination_matrix(4, "paper")
    msg = str(ei.value)
    assert "paper" in msg and "4" in msg and "6" in msg
    with pytest.raises(ValueError):
        T.build_topology("paper", 12)
    # exact size still works
    assert T.build_topology("paper", 6).matrix.shape == (6, 6)


def test_unknown_topology_and_rule_fail_loudly():
    with pytest.raises(ValueError, match="unknown topology"):
        T.combination_matrix(4, "hypercube")
    with pytest.raises(ValueError, match="rule"):
        T.combination_matrix(4, "ring", rule="perron")


# ---------------------------------------------------------------------------
# TopologySchedules: per-step matrices keep the combine contract
# ---------------------------------------------------------------------------

def _sched(kind, K=6, topo="ring", **kw):
    return T.make_schedule(kind, T.build_topology(topo, K), **kw)


@pytest.mark.parametrize("kind", sorted(T.SCHEDULES))
def test_schedule_matrices_all_doubly_stochastic(kind):
    s = _sched(kind, **({"p": 0.3, "period": 16}
                        if kind == "link_failure" else {}))
    assert s.matrices.ndim == 3
    for A in s.matrices:
        assert T.is_doubly_stochastic(A)
    assert T.is_doubly_stochastic(s.mean_matrix)


def test_static_schedule_is_the_base_matrix():
    s = _sched("static")
    assert s.static and s.period == 1
    np.testing.assert_allclose(s.stacked(), T.combination_matrix(6, "ring"))
    assert s.stacked().ndim == 2        # sparse/mesh backends stay eligible


def test_link_failure_limits():
    base = T.combination_matrix(6, "ring")
    s0 = _sched("link_failure", p=0.0, period=4)
    for A in s0.matrices:
        np.testing.assert_allclose(A, base)
    s1 = _sched("link_failure", p=1.0, period=4)
    for A in s1.matrices:
        np.testing.assert_allclose(A, np.eye(6))
    # deterministic for a fixed seed; p strictly between: some variation
    sa = _sched("link_failure", p=0.4, period=16, seed=5)
    sb = _sched("link_failure", p=0.4, period=16, seed=5)
    np.testing.assert_array_equal(sa.matrices, sb.matrices)
    assert any(not np.allclose(A, base) for A in sa.matrices)


def test_gossip_is_single_pairwise_exchange():
    s = _sched("gossip", period=32, seed=1)
    edges = set(T.build_topology("ring", 6).edges)
    for A in s.matrices:
        off = np.argwhere((A > 0) & ~np.eye(6, dtype=bool))
        assert len(off) == 2                     # one symmetric pair
        l, k = sorted(off[0])
        assert (l, k) in edges
        assert A[l, k] == 0.5
    # over the period every edge should appear at least once (6 edges, 32 draws)
    seen = {tuple(sorted(np.argwhere((A > 0) & ~np.eye(6, dtype=bool))[0]))
            for A in s.matrices}
    assert seen == edges


def test_round_robin_is_matchings_covering_all_edges():
    t = T.build_topology("paper", 6)
    s = T.make_schedule("round_robin", t)
    covered = set()
    for A in s.matrices:
        off = {tuple(sorted(e)) for e in
               map(tuple, np.argwhere((A > 0) & ~np.eye(6, dtype=bool)))}
        # matching: no agent appears in two active edges of one round
        agents = [a for e in off for a in e]
        assert len(agents) == len(set(agents))
        covered |= off
    assert covered == {tuple(sorted(e)) for e in t.edges}


def test_schedule_mean_mixing_rate_orders_kinds():
    """Static ring mixes faster in expectation than its failing/gossip
    variants (fewer active links per step ⇒ weaker expected contraction)."""
    static = _sched("static")
    lossy = _sched("link_failure", p=0.5, period=64)
    gossip = _sched("gossip", period=64)
    assert static.mean_mixing_rate < lossy.mean_mixing_rate
    assert static.mean_mixing_rate < gossip.mean_mixing_rate


def test_make_schedule_unknown_kind():
    with pytest.raises(ValueError, match="unknown topology schedule"):
        _sched("adaptive")


def test_schedule_k1_degenerates():
    s = T.make_schedule("gossip", T.build_topology("ring", 1))
    assert s.matrices.shape == (1, 1, 1)
