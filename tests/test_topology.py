"""Combination-matrix properties (paper Assumption 6 + Thm 1 quantities).

Former hypothesis property tests run as seeded parametrize grids so tier-1
collects with no optional dependencies.
"""
import numpy as np
import pytest

from repro.core import topology as T

TOPOS = ["ring", "full", "star", "grid", "torus", "erdos", "paper"]


@pytest.mark.parametrize("topo", TOPOS)
@pytest.mark.parametrize("K", [2, 4, 6, 9, 16])
def test_metropolis_doubly_stochastic_and_primitive(topo, K):
    if topo == "paper" and K != 6:
        pytest.skip("paper graph is K=6")
    A = T.combination_matrix(K, topo)
    assert T.is_doubly_stochastic(A)
    assert T.is_primitive(A)


@pytest.mark.parametrize("K", [3, 8, 16])
def test_uniform_rule_doubly_stochastic(K):
    A = T.combination_matrix(K, "ring", rule="uniform")
    assert T.is_doubly_stochastic(A)


@pytest.mark.parametrize("K", [3, 5, 11, 24])
@pytest.mark.parametrize("seed", [0, 17, 50])
def test_erdos_connected_and_mixing(K, seed):
    A = T.combination_matrix(K, "erdos", seed=seed)
    assert T.is_doubly_stochastic(A)
    lam2 = T.mixing_rate(A)
    assert 0.0 <= lam2 < 1.0  # connected + primitive => strict contraction


def test_mixing_rate_orders_topologies():
    """Denser graphs mix faster: λ₂(full) < λ₂(ring) for the same K."""
    K = 12
    lam_full = T.mixing_rate(T.combination_matrix(K, "full"))
    lam_ring = T.mixing_rate(T.combination_matrix(K, "ring"))
    assert lam_full < lam_ring < 1.0


def test_full_graph_metropolis_is_uniform_average():
    K = 5
    A = T.combination_matrix(K, "full")
    assert np.allclose(A, np.ones((K, K)) / K)
    assert T.mixing_rate(A) < 1e-8


def test_paper_graph_shape():
    A = T.combination_matrix(6, "paper")
    assert A.shape == (6, 6)
    assert T.is_doubly_stochastic(A)
    # 8 undirected edges -> 16 off-diagonal nonzeros
    assert (A > 0).sum() - (np.diagonal(A) > 0).sum() == 16


def test_permute_offsets_ring():
    K = 8
    A = T.combination_matrix(K, "ring")
    offs = T.permute_offsets(A, K)
    assert sorted(offs) == [1, K - 1]
    assert T.is_circulant(A)


def test_star_not_circulant():
    A = T.combination_matrix(6, "star")
    assert not T.is_circulant(A)


@pytest.mark.parametrize("K", [2, 3, 4, 6, 8, 12, 16])
def test_contraction_bound(K):
    """‖(Aᵀ − 11ᵀ/K) x‖ ≤ λ₂ ‖x‖ for mean-zero x (Thm 1 mechanism)."""
    A = T.combination_matrix(K, "ring")
    lam2 = T.mixing_rate(A)
    rng = np.random.default_rng(K)
    x = rng.normal(size=(K, 5))
    x -= x.mean(axis=0, keepdims=True)
    y = A.T @ x
    assert np.linalg.norm(y) <= lam2 * np.linalg.norm(x) + 1e-9
