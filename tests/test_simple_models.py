"""The paper's own models (SineMLP, FewShotCNN)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.fewshot import FewShotSampler
from repro.models.simple import FewShotCNN, SineMLP


def test_sine_mlp_shapes_and_architecture():
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    params = model.init(jax.random.key(0))
    # paper App. D.1: 2 hidden layers of 40 units
    assert params["l0"]["w"].shape == (1, 40)
    assert params["l1"]["w"].shape == (40, 40)
    assert params["l2"]["w"].shape == (40, 1)
    x = jnp.linspace(-5, 5, 32).reshape(-1, 1)
    y = model.forward(params, x)
    assert y.shape == (32, 1)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_sine_mlp_can_fit_one_sinusoid():
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    params = model.init(jax.random.key(0))
    x = jnp.linspace(-5, 5, 64).reshape(-1, 1)
    y = 2.0 * jnp.sin(x + 0.5)
    loss0 = float(model.loss_fn(params, (x, y)))
    step = jax.jit(lambda p: jax.tree.map(
        lambda a, b: a - 0.05 * b, p, jax.grad(model.loss_fn)(p, (x, y))))
    for _ in range(2000):   # small Finn-style init → slow plain GD
        params = step(params)
    loss1 = float(model.loss_fn(params, (x, y)))
    assert loss1 < 0.2 * loss0


def test_cnn_shapes_and_accuracy_api():
    cfg = get_config("omniglot_cnn")
    sampler = FewShotSampler(n_classes=30, n_way=cfg.vocab_size, seed=0)
    model = FewShotCNN(cfg, image_hw=sampler.image_hw)
    params = model.init(jax.random.key(0))
    (sx, sy), _ = sampler.sample(3)
    logits = model.forward(params, jnp.asarray(sx[0]))
    assert logits.shape == (sx.shape[1], cfg.vocab_size)
    acc = model.accuracy(params, (jnp.asarray(sx[0]), jnp.asarray(sy[0])))
    assert 0.0 <= float(acc) <= 1.0


def test_cnn_learns_an_episode():
    cfg = get_config("omniglot_cnn")
    sampler = FewShotSampler(n_classes=30, n_way=5, k_shot=5, seed=1)
    model = FewShotCNN(cfg, image_hw=sampler.image_hw)
    params = model.init(jax.random.key(0))
    (sx, sy), _ = sampler.sample(1)
    batch = (jnp.asarray(sx[0]), jnp.asarray(sy[0]))
    for _ in range(100):
        g = jax.grad(model.loss_fn)(params, batch)
        params = jax.tree.map(lambda p, gg: p - 0.05 * gg, params, g)
    assert float(model.accuracy(params, batch)) > 0.9
