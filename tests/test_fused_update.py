"""Fused combine-then-update outer step: parity with the unfused chain.

The fused path (kernels/dif_combine.fused_combine_update driven by
core/fused.make_fused_outer) must reproduce the trainer's unfused
``clip → opt.update → strategy.apply/combine`` composition on arbitrary
ragged mixed-dtype pytrees, including every gating and schedule wrinkle:
``grad_clip=0.0`` (total clip), ``weight_decay > 0``, ``combine_every > 1``
(skipped comm steps still advance the moments), and stacked dynamic
schedules.  f32 leaves are held to near-exact tolerance; bf16 leaves get a
rounding-level budget — the fused path keeps the clipped gradient in fp32
for the moment update where the unfused chain rounds it to bf16 first.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MetaConfig, init_state, make_meta_step
from repro.core import diffusion, topology, update
from repro.core.fused import make_fused_outer, fused_unsupported_reason
from repro.core.meta_trainer import TopologyConfig, UpdateConfig
from repro.kernels.dif_combine.dif_combine import (dif_combine,
                                                   fused_combine_update)
from repro.optim import (adam, momentum, sgd, clip_by_global_norm,
                         get_optimizer)
from repro.optim.optimizers import Optimizer

K = 4


def ragged_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(K, 7, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(K, 3)), jnp.float32),
        "c": jnp.asarray(rng.normal(size=(K, 17)), jnp.bfloat16),
    }


def fake_grads(w, step):
    # deterministic, param- and step-dependent so moments actually move
    return jax.tree.map(
        lambda p: (p * 0.1 + 0.3 * (1 + step % 3)).astype(p.dtype), w)


def ring_table(stacked=False):
    topo = topology.build_topology("ring", K)
    if not stacked:
        return topo.matrix
    return topology.make_schedule("link_failure", topo, p=0.5, period=3,
                                  seed=1).stacked()


def unfused_run(opt, strategy, A, comm, grad_clip, params, steps):
    """Mirror of the trainer's unfused post-gradient block (meta_trainer
    make_meta_step): per-agent clip, opt.update, gated strategy apply."""
    An = np.asarray(A, np.float32) if A is not None else None
    st = opt.init(params)
    w = params
    for step in range(steps):
        grads = fake_grads(w, step)
        if grad_clip is not None:
            grads = jax.vmap(
                lambda g: clip_by_global_norm(g, grad_clip))(grads)
        upd, st = opt.update(grads, st, w)
        if strategy in ("none", "cta"):
            w = update.local_update(w, upd)
            continue
        gate = float(comm.is_comm_step(step))
        if strategy == "centralized":
            As = np.full((K, K), 1.0 / K, np.float32)
        else:
            As = An[step % An.shape[0]] if An.ndim == 3 else An
        Ae = gate * As + (1 - gate) * np.eye(K, dtype=np.float32)

        def mix(t):
            return jax.tree.map(
                lambda x: jnp.einsum(
                    "lk,lm->km", jnp.asarray(Ae),
                    x.astype(jnp.float32).reshape(K, -1)).reshape(x.shape),
                t)

        if strategy == "consensus":
            w = jax.tree.map(
                lambda m, u, p: (m + u.astype(jnp.float32)).astype(p.dtype),
                mix(w), upd, w)
        else:                                   # atc / centralized
            phi = jax.tree.map(
                lambda p, u: p.astype(jnp.float32) + u.astype(jnp.float32),
                w, upd)
            w = jax.tree.map(lambda m, p: m.astype(p.dtype), mix(phi), w)
    return w, st


def fused_run(opt, strategy, A, comm, grad_clip, params, steps):
    outer = make_fused_outer(opt, strategy, comm, A, grad_clip=grad_clip,
                             num_agents=K, interpret=True)
    st = opt.init(params)
    w = params
    for step in range(steps):
        w, st = outer(w, fake_grads(w, step), st, jnp.asarray(step))
    return w, st


def assert_tree_close(got, want, f32_tol=5e-6, bf16_tol=2e-2, like=None):
    """``like``: tree whose leaf dtypes pick the tolerance — fp32 moments
    of a bf16 param leaf still carry bf16-rounding deviation (the unfused
    chain rounds the clipped gradient to bf16 before the moment update)."""
    refs = dict(jax.tree_util.tree_flatten_with_path(want)[0])
    dts = dict(jax.tree_util.tree_flatten_with_path(like or got)[0])
    for path, g in jax.tree_util.tree_flatten_with_path(got)[0]:
        ref = refs[path]
        tol = bf16_tol if dts[path].dtype == jnp.bfloat16 else f32_tol
        err = float(jnp.max(jnp.abs(g.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        assert err <= tol, f"{path}: err {err} > {tol} ({g.dtype})"


CASES = [
    # (name, opt, strategy, stacked, grad_clip, every)
    ("adam_atc_clip", lambda: adam(1e-2), "atc", False, 1.0, 1),
    ("adam_consensus_wd", lambda: adam(1e-2, weight_decay=1e-3),
     "consensus", False, None, 1),
    ("adam_atc_clip0", lambda: adam(1e-2), "atc", False, 0.0, 1),
    ("momentum_atc", lambda: momentum(1e-2, beta=0.8), "atc", False,
     None, 1),
    ("sgd_none", lambda: sgd(1e-2), "none", False, 0.5, 1),
    ("adam_centralized", lambda: adam(1e-2), "centralized", False,
     None, 1),
    ("adam_atc_every2", lambda: adam(1e-2), "atc", False, 1.0, 2),
    ("sgd_consensus_every3", lambda: sgd(1e-2), "consensus", False,
     None, 3),
    ("adam_atc_stacked", lambda: adam(1e-2), "atc", True, 1.0, 1),
    ("adam_atc_stacked_every2", lambda: adam(1e-2), "atc", True,
     None, 2),
]


@pytest.mark.parametrize("name,mk,strategy,stacked,clip,every",
                         CASES, ids=[c[0] for c in CASES])
def test_fused_matches_unfused(name, mk, strategy, stacked, clip, every):
    params = ragged_params()
    A = None if strategy in ("none",) else ring_table(stacked)
    comm = update.CommSchedule(every)
    w_f, st_f = fused_run(mk(), strategy, A, comm, clip, params, steps=5)
    w_u, st_u = unfused_run(mk(), strategy, A, comm, clip, params, steps=5)
    assert_tree_close(w_f, w_u)
    if hasattr(st_f, "mu"):
        assert int(st_f.step) == int(st_u.step) == 5
        assert_tree_close(st_f.mu, st_u.mu, like=params)
        assert_tree_close(st_f.nu, st_u.nu, like=params)
    elif hasattr(st_f, "velocity"):
        assert_tree_close(st_f.velocity, st_u.velocity, like=params)


def test_skipped_comm_steps_still_advance_moments():
    """combine_every=2: step 0 is a no-comm step (is_comm_step fires at
    every-1) — the mix must degenerate to identity while mu/nu move."""
    params = ragged_params()
    comm = update.CommSchedule(2)
    opt = adam(1e-2)
    outer = make_fused_outer(opt, "atc", comm, ring_table(), grad_clip=None,
                             num_agents=K, interpret=True)
    st0 = opt.init(params)
    w1, st1 = outer(params, fake_grads(params, 0), st0, jnp.asarray(0))
    assert int(st1.step) == 1
    assert float(jnp.max(jnp.abs(st1.mu["b"]))) > 0.0   # moments advanced
    # identity mix on the skipped step == plain local adam update
    w_ref, _ = unfused_run(adam(1e-2), "none", None, comm, None, params, 1)
    assert_tree_close(w1, w_ref)
    # ...and the next step does communicate: agents couple
    w2, _ = outer(w1, fake_grads(w1, 1), st1, jnp.asarray(1))
    w2_local, _ = outer(w1, fake_grads(w1, 1), st1, jnp.asarray(2))
    assert float(jnp.max(jnp.abs(w2["a"] - w2_local["a"]))) > 0.0


def test_total_clip_freezes_nothing_but_zeroes_direction():
    """grad_clip=0.0 zeroes every gradient: adam still bias-corrects a
    0/0 -> 0 direction (eps keeps it finite) so params only decay by wd."""
    params = ragged_params()
    comm = update.CommSchedule(1)
    opt = adam(1e-2)
    outer = make_fused_outer(opt, "none", comm, None, grad_clip=0.0,
                             num_agents=K, interpret=True)
    w1, st1 = outer(params, fake_grads(params, 0), opt.init(params),
                    jnp.asarray(0))
    assert_tree_close(w1, params, f32_tol=0.0, bf16_tol=0.0)
    assert float(jnp.max(jnp.abs(st1.mu["a"]))) == 0.0


def test_fused_backend_registered():
    assert "fused" in diffusion.combine_backends()
    # the combine-only face serves the cta pre-mix: must equal dense
    A = ring_table()
    phi = ragged_params()
    got = diffusion.make_combine("fused", A=A, interpret=True)(phi, 0)
    want = diffusion.make_combine("dense", A=A)(phi, 0)
    assert_tree_close(got, want)
    # stacked schedules stay on the fused backend (step-indexed capable)
    assert diffusion.resolve_schedule_backend(
        "fused", ring_table(stacked=True)) == "fused"
    # 'auto' never volunteers the fused path — it changes optimizer wiring
    assert diffusion.select_backend(A) != "fused"
    assert diffusion.select_backend(ring_table(stacked=True)) != "fused"


def test_unqualified_optimizer_raises():
    bare = Optimizer(init=lambda p: (), update=lambda g, s, p: (g, s))
    assert fused_unsupported_reason(bare, "atc") is not None
    with pytest.raises(ValueError, match="FusedSpec"):
        make_fused_outer(bare, "atc", update.CommSchedule(1), ring_table())
    with pytest.raises(ValueError, match="no fused composition"):
        make_fused_outer(adam(1e-2), "mystery", update.CommSchedule(1),
                         ring_table())


def test_agent_count_mismatch_raises():
    with pytest.raises(ValueError, match="K=4.*num_agents=6"):
        make_fused_outer(adam(1e-2), "atc", update.CommSchedule(1),
                         ring_table(), num_agents=6)


def test_kernel_shape_errors_carry_both_numbers():
    w = jnp.zeros((K, 512), jnp.float32)
    g = jnp.zeros((K, 512), jnp.float32)
    tab = jnp.eye(K)[None]
    sel = jnp.zeros((1, 1), jnp.int32)
    ctl = jnp.asarray([[1.0, 1.0, 1.0]], jnp.float32)
    scale = jnp.ones((K, 1), jnp.float32)
    with pytest.raises(ValueError, match="100.*128"):
        fused_combine_update(tab, sel, ctl, scale, w, g, w, w, kind="adam",
                             lr=1e-2, block_m=100, interpret=True)
    with pytest.raises(ValueError, match=r"\(1, 4, 4\).*K=8"):
        fused_combine_update(tab, sel, ctl, jnp.ones((8, 1)),
                             jnp.zeros((8, 512)), jnp.zeros((8, 512)),
                             jnp.zeros((8, 512)), jnp.zeros((8, 512)),
                             kind="adam", lr=1e-2, interpret=True)
    with pytest.raises(ValueError, match="512.*384"):
        dif_combine(jnp.eye(K), w, block_m=384, interpret=True)


def test_meta_step_fused_matches_dense_end_to_end():
    """Full trainer assembly: make_meta_step(backend='fused') vs 'dense'
    on the paper's sine setting — same losses, params within tolerance."""
    from repro.configs import get_config
    from repro.data.sine import agent_sine_distributions, stacked_agent_batch
    from repro.models.simple import SineMLP

    model = SineMLP(get_config("sine_mlp"))

    def run(backend):
        mcfg = MetaConfig(
            num_agents=6, tasks_per_agent=2, inner_lr=0.01,
            outer_optimizer="adam", outer_lr=1e-3, grad_clip=1.0,
            update_config=UpdateConfig(strategy="atc", inner="maml",
                                       backend=backend, combine_every=2),
            topology_config=TopologyConfig(graph="paper"))
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=True)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        dists = agent_sine_distributions(6, seed=0)
        losses = []
        for _ in range(6):
            support, query = stacked_agent_batch(dists, 2, 10)
            state, metrics = step(state,
                                  jax.tree.map(jnp.asarray, support),
                                  jax.tree.map(jnp.asarray, query))
            losses.append(float(metrics["loss"]))
        return state, losses

    st_f, loss_f = run("fused")
    st_d, loss_d = run("dense")
    np.testing.assert_allclose(loss_f, loss_d, rtol=1e-4)
    assert_tree_close(st_f.params, st_d.params, f32_tol=1e-4)
    assert_tree_close(st_f.opt_state.mu, st_d.opt_state.mu, f32_tol=1e-4)
    assert int(st_f.opt_state.step) == int(st_d.opt_state.step) == 6


def test_meta_step_fused_rejects_custom_optimizer():
    from repro.configs import get_config
    from repro.models.simple import SineMLP

    model = SineMLP(get_config("sine_mlp"))
    mcfg = MetaConfig(
        num_agents=6, tasks_per_agent=2, inner_lr=0.01,
        update_config=UpdateConfig(strategy="atc", backend="fused"),
        topology_config=TopologyConfig(graph="paper"))
    bare = Optimizer(init=lambda p: (), update=lambda g, s, p: (g, s))
    with pytest.raises(ValueError, match="FusedSpec"):
        make_meta_step(model.loss_fn, mcfg, optimizer=bare)
