"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward + one Dif-MAML train step on CPU with
shape assertions and NaN checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import MetaConfig, init_state, make_meta_step
from repro.models.transformer import build_model

ARCHS = list_archs()  # the 10 assigned architectures


def _reduced(arch):
    cfg = get_config(arch).reduced()
    return dataclasses.replace(cfg, attn_q_chunk=None, dtype="float32")


def _batch(cfg, B=2, S=16, seed=0):
    k = jax.random.key(seed)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.arch_type == "audio":
        batch["encoder_frames"] = jax.random.normal(
            jax.random.key(seed + 1), (B, cfg.encoder_frames, cfg.d_model)) * 0.1
    if cfg.arch_type == "vlm":
        batch["image_patches"] = jax.random.normal(
            jax.random.key(seed + 1), (B, cfg.num_patches, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = _reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_dif_maml_train_step(arch):
    """K=2 agents, 1 task each, one full meta-iteration: loss finite,
    params updated, no NaNs anywhere in the updated launch models."""
    cfg = _reduced(arch)
    model = build_model(cfg)
    mcfg = MetaConfig(num_agents=2, tasks_per_agent=1, inner_lr=1e-3,
                      mode=cfg.meta_mode, combine="dense", topology="ring",
                      outer_optimizer="sgd", outer_lr=1e-3)
    state = init_state(jax.random.key(0), lambda k: model.init(k), mcfg)
    step = make_meta_step(model.loss_fn, mcfg)

    def stack(b):
        return jax.tree.map(lambda x: x[None, None].repeat(2, 0), b)

    support = stack(_batch(cfg, 2, 16, seed=1))
    query = stack(_batch(cfg, 2, 16, seed=2))
    new_state, metrics = step(state, support, query)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state.step) == 1
    for leaf in jax.tree.leaves(new_state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    moved = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in
                zip(jax.tree.leaves(new_state.params),
                    jax.tree.leaves(state.params)))
    assert moved > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_respects_limits(arch):
    cfg = _reduced(arch)
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    # hybrid keeps one full period; others ≤ 2 scan steps
    assert cfg.num_layers <= max(2, cfg.attn_every, 2 * (cfg.cross_attn_every or 0))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dimensions."""
    expect = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, None, 102400),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "mamba2-130m": (24, 768, None, None, None, 50280),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
    }
    for arch in ARCHS:
        cfg = get_config(arch)
        L, d, H, KV, ff, V = expect[cfg.name]
        assert cfg.num_layers == L and cfg.d_model == d
        assert cfg.vocab_size == V
        if H is not None:
            assert cfg.num_heads == H and cfg.num_kv_heads == KV
        if ff is not None:
            assert (cfg.d_ff == ff or cfg.moe_hidden == ff)
    # family-specific details
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.use_mla and ds.kv_lora_rank == 512 and ds.num_experts == 64 \
        and ds.experts_per_token == 6 and ds.moe_hidden == 1408
    mx = get_config("mixtral-8x22b")
    assert mx.num_experts == 8 and mx.experts_per_token == 2 \
        and mx.sliding_window == 4096
    jb = get_config("jamba-1.5-large-398b")
    assert jb.attn_every == 8 and jb.num_experts == 16 and jb.ssm_state == 128
    m2 = get_config("mamba2-130m")
    assert m2.ssm_state == 128 and m2.d_ff == 0
    qw = get_config("qwen2-1.5b")
    assert qw.qkv_bias
