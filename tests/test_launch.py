"""Launch-layer logic that runs without the 512-device dry-run env."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import INPUT_SHAPES, get_config
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh

MESH1 = abstract_mesh((16, 16), ("data", "model"))
MESH2 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
AMESH2 = abstract_mesh((16, 16), ("agent", "model"))
AMESH3 = abstract_mesh((8, 2, 16), ("agent", "data", "model"))


def test_agent_count_placements():
    qw = get_config("qwen2-7b")          # placement=data
    mx = get_config("mixtral-8x22b")     # placement=pod
    assert S.agent_count(qw, MESH1) == 16
    assert S.agent_count(qw, MESH2) == 32
    assert S.agent_count(mx, MESH1) == 1
    assert S.agent_count(mx, MESH2) == 2


def test_agent_count_agent_axis_wins():
    # a first-class agent axis overrides placement for every config
    qw = get_config("qwen2-7b")          # placement=data
    mx = get_config("mixtral-8x22b")     # placement=pod
    for cfg in (qw, mx):
        assert S.agent_count(cfg, AMESH2) == 16
        assert S.agent_count(cfg, AMESH3) == 8


def test_batch_geometry_divides_exactly():
    # (prefill shapes lower a plain forward — no meta geometry needed)
    shape = INPUT_SHAPES["train_4k"]
    for arch in ["qwen2-7b", "mixtral-8x22b"]:
        cfg = get_config(arch)
        for mesh in (MESH1, MESH2):
            K = S.agent_count(cfg, mesh)
            T, tb = S.batch_geometry(cfg, shape, K)
            assert K * T * tb * 2 == shape.global_batch


def test_batch_geometry_rejects_indivisible_batch():
    """K ∤ B (or an odd per-agent batch) must fail loudly with the numbers,
    not vanish rows in the (K, T, 2·tb) fold."""
    import dataclasses
    from repro.configs.base import InputShape
    cfg = get_config("qwen2-7b")
    with pytest.raises(ValueError) as ei:
        S.batch_geometry(cfg, InputShape("x", 16, 10, "train"), K=4)
    msg = str(ei.value)
    assert "global_batch=10" in msg and "K=4" in msg and "8" in msg
    # per-agent batch below the support+query minimum
    with pytest.raises(ValueError, match="minimum 8"):
        S.batch_geometry(cfg, InputShape("x", 16, 4, "train"), K=4)
    # odd per-agent batch cannot split into support+query halves
    with pytest.raises(ValueError):
        S.batch_geometry(cfg, InputShape("x", 16, 12, "train"), K=4)


def test_batch_geometry_T_falls_back():
    """T retreats from cfg.meta_tasks toward 1 until it divides the
    per-agent half batch — and WARNS with the requested and effective T
    (silent degradation erased the eq. 4 multi-task average)."""
    import dataclasses
    from repro.configs.base import InputShape
    cfg = dataclasses.replace(get_config("qwen2-7b"), meta_tasks=4)
    # half = 6: 6 % 4 != 0, 6 % 3 == 0 -> T=3, tb=2
    with pytest.warns(RuntimeWarning, match=r"meta_tasks=4.*T=3"):
        assert S.batch_geometry(cfg, InputShape("x", 16, 24, "train"),
                                K=2) == (3, 2)
    # half = 5: falls all the way back to T=1, tb=5
    with pytest.warns(RuntimeWarning, match=r"meta_tasks=4.*T=1"):
        assert S.batch_geometry(cfg, InputShape("x", 16, 20, "train"),
                                K=2) == (1, 5)
    # exact fit keeps meta_tasks — and stays silent
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")
        assert S.batch_geometry(cfg, InputShape("x", 16, 16, "train"),
                                K=2) == (4, 1)


def test_split_meta_batch_layout():
    cfg = get_config("qwen2-7b")
    B, Sq = 32, 8
    batch = {"tokens": jnp.arange(B * Sq).reshape(B, Sq)}
    sup, qry = S.split_meta_batch(cfg, batch, K=4, T=2, tb=2)
    assert sup["tokens"].shape == (4, 2, 2, Sq)
    assert qry["tokens"].shape == (4, 2, 2, Sq)
    # support/query are disjoint halves of each task's rows
    joined = jnp.concatenate([sup["tokens"], qry["tokens"]], axis=2)
    np.testing.assert_array_equal(joined.reshape(B, Sq), batch["tokens"])


def test_input_specs_train_shapes():
    specs = S.input_specs(get_config("qwen2-7b"), "train_4k")
    assert specs["tokens"].shape == (256, 4096)
    assert specs["labels"].dtype == jnp.int32
    w = S.input_specs(get_config("whisper-large-v3"), "train_4k")
    assert w["encoder_frames"].shape == (256, 1500, 1280)
    v = S.input_specs(get_config("llama-3.2-vision-90b"), "train_4k")
    assert v["image_patches"].shape == (256, 576, 8192)


def test_input_specs_decode_cache():
    specs = S.input_specs(get_config("command-r-35b"), "decode_32k")
    assert specs["token"].shape == (128, 1)
    assert specs["pos"].shape == (128,)
    leaves = jax.tree.leaves(specs["cache"])
    # 40 layers of K + V at (B, S, KV, hd)
    assert any(l.shape == (40, 128, 32768, 8, 128) for l in leaves)


def test_decode_cache_swa_is_window_bounded():
    specs = S.input_specs(get_config("mixtral-8x22b"), "long_500k")
    for l in jax.tree.leaves(specs["cache"]):
        assert l.shape[2] <= 4096   # ring buffer, not 524288


def test_mamba_long_context_cache_constant():
    specs = S.input_specs(get_config("mamba2-130m"), "long_500k")
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(specs["cache"]))
    assert total < 50e6             # O(1) state, not O(seq)


def test_train_bundle_builds_on_host_mesh():
    """Full bundle construction + one real step on the host mesh."""
    from repro.configs.base import InputShape
    cfg = get_config("qwen2-1.5b").reduced()
    INPUT_SHAPES["t_test"] = InputShape("t_test", 16, 8, "train")
    mesh = make_host_mesh()
    with mesh:
        bundle = S.build_train(cfg, mesh, "t_test")
        state = bundle.init_state(seed=0)
        batch = {
            "tokens": jnp.zeros((8, 16), jnp.int32),
            "labels": jnp.zeros((8, 16), jnp.int32),
        }
        state2, metrics = jax.jit(bundle.step_fn)(state, batch)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(state2.step) == 1
    del INPUT_SHAPES["t_test"]


def test_register_input_shape_idempotent_and_conflict():
    """The registry helper (replaces raw INPUT_SHAPES mutation): same
    value re-registers silently, a different geometry under the same name
    fails loudly unless override=True."""
    from repro.configs import register_input_shape
    from repro.configs.base import InputShape
    shape = InputShape("reg_test", 16, 8, "train")
    try:
        register_input_shape(shape)
        assert INPUT_SHAPES["reg_test"] is shape
        register_input_shape(InputShape("reg_test", 16, 8, "train"))  # no-op
        clash = InputShape("reg_test", 32, 8, "train")
        with pytest.raises(ValueError, match="already registered"):
            register_input_shape(clash)
        register_input_shape(clash, override=True)
        assert INPUT_SHAPES["reg_test"].seq_len == 32
    finally:
        del INPUT_SHAPES["reg_test"]


def test_register_input_shape_protects_builtins():
    from repro.configs import register_input_shape
    from repro.configs.base import InputShape
    with pytest.raises(ValueError, match="built in"):
        register_input_shape(InputShape("train_4k", 16, 8, "train"),
                             override=True)


def test_input_shape_scope_restores_registry():
    from repro.configs import input_shape_scope
    from repro.configs.base import InputShape
    before = dict(INPUT_SHAPES)
    with input_shape_scope(InputShape("scoped_a", 16, 8, "train")) as sh:
        assert INPUT_SHAPES["scoped_a"] is sh
        # shadow a non-builtin name, restore the prior entry on exit
        with input_shape_scope(InputShape("scoped_a", 32, 8, "train")):
            assert INPUT_SHAPES["scoped_a"].seq_len == 32
        assert INPUT_SHAPES["scoped_a"] is sh
    assert dict(INPUT_SHAPES) == before


def test_meta_config_for_uses_arch_fields():
    cfg = get_config("deepseek-v2-lite-16b")
    mcfg = S.meta_config_for(cfg, K=16, T=2)
    assert mcfg.mode == "fomaml"
    assert mcfg.num_agents == 16
    assert mcfg.outer_optimizer == "momentum"
    mcfg1 = S.meta_config_for(cfg, K=1, T=2)
    assert mcfg1.combine == "none"   # degenerate single-agent case


def test_opt_state_axes_match_structures():
    p_axes = {"w": ("agent", "embed", "ffn")}
    assert S.opt_state_axes("sgd", p_axes) == ()
    mom = S.opt_state_axes("momentum", p_axes)
    assert mom.velocity == p_axes
    ad = S.opt_state_axes("adam", p_axes)
    assert ad.mu == p_axes and ad.nu == p_axes and ad.step == ()
