"""Agent-axis mesh composition: factories, full-stack compile, wire budget.

The factory-validation tests run in-process (single device).  The
end-to-end test compiles a real reduced train step on an 8-forced-host-
device (agent=4, model=2) mesh in a subprocess and runs the same
``agent_combine_check`` budget the production dry-run asserts: the ring
combine's collective-permute bytes must be deg·(per-agent f32 shard) —
NOT K·shard — with TP composing underneath.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.mesh import make_host_mesh, make_production_mesh


def test_make_production_mesh_rejects_non_factoring():
    with pytest.raises(ValueError, match="agents=3"):
        make_production_mesh(agents=3)
    with pytest.raises(ValueError, match="512"):
        make_production_mesh(agents=3, multi_pod=True)
    with pytest.raises(ValueError):
        make_production_mesh(agents=0)


def test_make_host_mesh_agent_rejects_non_factoring():
    # the single-device test runtime cannot hold 2 agents
    with pytest.raises(ValueError, match="agents=2"):
        make_host_mesh(agents=2)


def test_make_host_mesh_agent_trivial_extent():
    mesh = make_host_mesh(agents=1, model=1)
    assert mesh.axis_names == ("agent", "model")
    assert mesh.devices.shape == (1, 1)


def test_make_host_mesh_legacy_clamp_warns():
    # the legacy path keeps its clamp semantics but reports both numbers
    with pytest.warns(RuntimeWarning, match=r"data=4.*using.*data=1"):
        mesh = make_host_mesh(data=4)
    assert mesh.devices.shape == (1, 1)     # effective extents unchanged


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import jax
    import numpy as np
    from repro.compat import mesh_axis_sizes
    from repro.configs import INPUT_SHAPES, get_config
    from repro.configs.base import InputShape
    from repro.core import diffusion
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    from repro.launch.hlo_cost import agent_combine_check, tree_shard_bytes

    mesh = make_host_mesh(model=2, agents=4)
    assert mesh.axis_names == ("agent", "model"), mesh.axis_names
    cfg = get_config("qwen2-7b").reduced()
    INPUT_SHAPES["t_2d"] = InputShape("t_2d", 32, 8, "train")
    with mesh:
        bundle = S.build_train(cfg, mesh, "t_2d",
                               combine_override="mesh_sparse_dynamic")
        assert bundle.K == 4
        jitted = jax.jit(bundle.step_fn,
                         in_shardings=(bundle.state_shardings,
                                       bundle.batch_shardings),
                         out_shardings=(bundle.state_shardings, None),
                         donate_argnums=(0,))
        hlo = jitted.lower(bundle.state_specs,
                           S.input_specs(cfg, "t_2d")).compile().as_text()
    # the combine permutes the wire dtype (bf16 payloads ride as 2-byte
    # u16), so the budget window is sized at wire_elem_bytes — half of
    # what the old hard-coded f32 sizing would demand
    assert bundle.combine_dtype == "bfloat16", bundle.combine_dtype
    shard = tree_shard_bytes(
        bundle.state_shardings.params, bundle.state_specs.params,
        mesh_axis_sizes(mesh),
        elem_bytes=diffusion.wire_elem_bytes(bundle.combine_dtype))
    deg = bundle.schedule.ir().degree
    assert deg == 2, deg                     # ring: offsets ±1
    budget = agent_combine_check(hlo, 8, degree=deg, shard_bytes=shard,
                                 wire_dtype=bundle.combine_dtype)
    assert budget["ok"], budget
    # the discriminating claims: K·shard would blow the window open, and
    # an f32 wire would overshoot the halved ceiling
    assert budget["permute_bytes"] < bundle.K * shard, budget
    assert budget["permute_bytes"] < deg * 2 * shard, budget
    print("MESH2D_BUDGET_OK", budget["permute_bytes"], budget["degree"])
""")


SCRIPT_3D = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "src")
    import jax
    from repro.compat import mesh_axis_sizes
    from repro.configs import INPUT_SHAPES, get_config
    from repro.configs.base import InputShape
    from repro.core import diffusion
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    from repro.launch.hlo_cost import agent_combine_check, tree_shard_bytes

    # 3D (agent, data, model): intra-agent data parallelism underneath the
    # diffusion axis, TP underneath that — the production (8, 2, 16) shape
    # collapsed onto 8 host devices
    mesh = make_host_mesh(data=2, model=2, agents=2)
    assert mesh.axis_names == ("agent", "data", "model"), mesh.axis_names
    cfg = get_config("qwen2-7b").reduced()
    INPUT_SHAPES["t_3d"] = InputShape("t_3d", 32, 8, "train")
    with mesh:
        bundle = S.build_train(cfg, mesh, "t_3d",
                               combine_override="mesh_sparse_dynamic")
        assert bundle.K == 2
        jitted = jax.jit(bundle.step_fn,
                         in_shardings=(bundle.state_shardings,
                                       bundle.batch_shardings),
                         out_shardings=(bundle.state_shardings, None),
                         donate_argnums=(0,))
        hlo = jitted.lower(bundle.state_specs,
                           S.input_specs(cfg, "t_3d")).compile().as_text()
    shard = tree_shard_bytes(
        bundle.state_shardings.params, bundle.state_specs.params,
        mesh_axis_sizes(mesh),
        elem_bytes=diffusion.wire_elem_bytes(bundle.combine_dtype))
    deg = bundle.schedule.ir().degree
    budget = agent_combine_check(hlo, 8, degree=deg, shard_bytes=shard,
                                 wire_dtype=bundle.combine_dtype)
    assert budget["ok"], budget
    print("MESH3D_BUDGET_OK", budget["permute_bytes"], budget["degree"])
""")


def _run_subprocess_budget(script, marker):
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    assert marker in out.stdout, out.stderr[-2000:]


def test_train_step_2d_mesh_combine_budget():
    _run_subprocess_budget(SCRIPT, "MESH2D_BUDGET_OK")


def test_train_step_3d_mesh_combine_budget():
    _run_subprocess_budget(SCRIPT_3D, "MESH3D_BUDGET_OK")
