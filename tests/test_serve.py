"""The serving tier (repro.serve): low-rank deltas, the adapted-state
cache, and the batched-adapt + scanned-decode engine.

Pins the ISSUE's serving guarantees at test time: delta-reconstructed
adapted params stay within |Δ query loss| ≤ 1e-2 of the full adapted
params, factored storage actually compresses, and the cache's recurring
fast path returns the same states it was given.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.serve import (AdaptRequest, AdaptedStateCache, DenseLeaf,
                         LowRankLeaf, ServeEngine, apply_delta,
                         compress_delta, source_fingerprint, task_key)

# -- low-rank deltas ----------------------------------------------------------


def _rank_r_delta(rng, rows, cols, r):
    return (rng.standard_normal((rows, r)) @
            rng.standard_normal((r, cols))).astype(np.float32)


def test_compress_exact_for_low_rank_delta():
    """A delta that truly is rank-r factors losslessly (up to SVD fp) and
    reconstruction returns base + delta."""
    rng = np.random.default_rng(0)
    base = {"w": rng.standard_normal((64, 48)).astype(np.float32),
            "b": rng.standard_normal(48).astype(np.float32)}
    delta = {"w": _rank_r_delta(rng, 64, 48, 3),
             "b": rng.standard_normal(48).astype(np.float32) * 0.01}
    adapted = jax.tree.map(lambda b, d: b + d, base, delta)
    comp = compress_delta(base, adapted, rank=8, tol=0.3)
    assert isinstance(comp.leaves["w"], LowRankLeaf)
    assert isinstance(comp.leaves["b"], DenseLeaf)   # vectors stay dense
    rec = apply_delta(base, comp)
    np.testing.assert_allclose(np.asarray(rec["w"]), adapted["w"],
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(rec["b"]), adapted["b"])


def test_compression_ratio_exceeds_one():
    """The point of the factored store: rank-8 factors of a 256x256 delta
    must cost a fraction of the dense bytes."""
    rng = np.random.default_rng(1)
    base = {"w": np.zeros((256, 256), np.float32)}
    adapted = {"w": _rank_r_delta(rng, 256, 256, 4)}
    comp = compress_delta(base, adapted, rank=8, tol=0.3)
    assert isinstance(comp.leaves["w"], LowRankLeaf)
    assert comp.compression > 4.0
    assert comp.nbytes < comp.dense_nbytes


def test_fidelity_gate_falls_back_to_dense():
    """A full-rank delta under a tight tolerance must NOT be truncated —
    the gate degrades into bytes, never into loss."""
    rng = np.random.default_rng(2)
    base = {"w": np.zeros((64, 64), np.float32)}
    adapted = {"w": rng.standard_normal((64, 64)).astype(np.float32)}
    comp = compress_delta(base, adapted, rank=4, tol=0.05)
    assert isinstance(comp.leaves["w"], DenseLeaf)
    rec = apply_delta(base, comp)
    np.testing.assert_array_equal(np.asarray(rec["w"]), adapted["w"])


def test_tiny_matrix_stays_dense():
    """Factored storage must actually save bytes: an 8x8 leaf at rank 8
    would cost more factored than dense."""
    base = {"w": np.zeros((8, 8), np.float32)}
    adapted = {"w": np.ones((8, 8), np.float32)}
    comp = compress_delta(base, adapted, rank=8, tol=1.0)
    assert isinstance(comp.leaves["w"], DenseLeaf)


def test_higher_rank_folds_leading_dims():
    """3D leaves (e.g. stacked heads) fold leading dims into rows."""
    rng = np.random.default_rng(3)
    base = {"w": np.zeros((4, 32, 24), np.float32)}
    adapted = {"w": _rank_r_delta(rng, 4 * 32, 24, 2).reshape(4, 32, 24)}
    comp = compress_delta(base, adapted, rank=8, tol=0.3)
    leaf = comp.leaves["w"]
    assert isinstance(leaf, LowRankLeaf)
    assert leaf.shape == (4, 32, 24)
    np.testing.assert_allclose(leaf.materialize(), adapted["w"],
                               rtol=1e-4, atol=1e-4)


# -- cache keys + LRU ---------------------------------------------------------


class _Src:
    def __init__(self, vocab, seed):
        self.vocab = vocab
        self.seed = seed
        self.blob = np.zeros(3)           # non-primitive: not fingerprinted


def test_source_fingerprint_primitives_only():
    a, b = _Src(64, 0), _Src(64, 0)
    assert source_fingerprint(a) == source_fingerprint(b)
    assert source_fingerprint(_Src(64, 1)) != source_fingerprint(a)
    assert "blob" not in source_fingerprint(a)


def test_task_key_distinguishes_adapt_hyperparams():
    src = _Src(64, 0)
    k = task_key(src, 3, 2, 0.01)
    assert k == task_key(src, 3, 2, 0.01)
    assert k != task_key(src, 4, 2, 0.01)      # different domain
    assert k != task_key(src, 3, 1, 0.01)      # different steps
    assert k != task_key(src, 3, 2, 0.02)      # different lr


def test_cache_lru_eviction_and_counters():
    base = {"w": jnp.zeros((4, 4), jnp.float32)}
    cache = AdaptedStateCache(capacity=2)
    keys = [task_key(_Src(64, 0), d, 1, 0.01) for d in range(3)]
    for i, k in enumerate(keys):
        assert cache.lookup(k, base) is None                 # miss
        cache.insert(k, base, {"w": jnp.full((4, 4), float(i + 1))})
    # capacity 2: key 0 (least recently used) was evicted
    assert cache.evictions == 1
    assert keys[0] not in cache and keys[1] in cache and keys[2] in cache
    assert cache.lookup(keys[0], base) is None
    got = cache.lookup(keys[2], base)
    np.testing.assert_allclose(np.asarray(got["w"]),
                               np.full((4, 4), 3.0), rtol=1e-6)
    stats = cache.stats()
    assert stats["hits"] == 1 and stats["misses"] == 4
    assert stats["residents"] == 2 and stats["evictions"] == 1
    assert stats["compression"] >= 1.0


def test_cache_lookup_refreshes_recency():
    base = {"w": jnp.zeros(3)}
    cache = AdaptedStateCache(capacity=2)
    k = [task_key(_Src(64, 0), d, 1, 0.01) for d in range(3)]
    cache.insert(k[0], base, {"w": jnp.ones(3)})
    cache.insert(k[1], base, {"w": jnp.ones(3)})
    cache.lookup(k[0], base)                   # k0 becomes most recent
    cache.insert(k[2], base, {"w": jnp.ones(3)})
    assert k[0] in cache and k[1] not in cache  # k1 was the LRU victim


def test_cache_preserves_param_dtype():
    base = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    cache = AdaptedStateCache(capacity=2)
    k = task_key(_Src(64, 0), 0, 1, 0.01)
    cache.insert(k, base, {"w": jnp.ones((4, 4), jnp.bfloat16)})
    got = cache.lookup(k, base)
    assert got["w"].dtype == jnp.bfloat16


# -- the engine ---------------------------------------------------------------

P, G, B = 4, 4, 2


@pytest.fixture(scope="module")
def engine():
    cfg = ArchConfig(name="serve-test", arch_type="dense", num_layers=1,
                     d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     d_ff=64, vocab_size=128, dtype="float32", remat=False,
                     attn_q_chunk=None, inner_lr=1e-2, inner_steps=1)
    eng = ServeEngine(cfg, prompt_len=P, gen=G, batch=B, adapt_steps=2,
                      buckets=(1, 2, 4))
    params = eng.model.init(jax.random.key(0), jnp.float32)
    eng.load_params(params)
    return eng


@pytest.fixture(scope="module")
def episode(engine):
    from repro.launch.serve import make_support_source
    source = make_support_source(engine.cfg, P + G, B)
    # seed 3 draws three DISTINCT domains — duplicate domains share a
    # cache key by design (see test_duplicate_domains_alias_one_entry),
    # which would confound the per-task drift comparison below
    ep = source.eval_sample(3, seed=3, split="full")
    assert len(set(np.asarray(ep.domains).tolist())) == 3
    return source, ep


def test_engine_requires_params():
    cfg = ArchConfig(name="serve-noparams", arch_type="dense", num_layers=1,
                     d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     d_ff=64, vocab_size=128, dtype="float32", remat=False,
                     attn_q_chunk=None, inner_lr=1e-2, inner_steps=1)
    eng = ServeEngine(cfg, prompt_len=P, gen=G, batch=B)
    with pytest.raises(RuntimeError, match="load_params"):
        eng.adapt([AdaptRequest({"tokens": np.zeros((B, P + G))})])


def test_adapt_miss_then_hit_counters(engine, episode):
    source, ep = episode
    reqs = engine.requests_from_episode(source, ep)
    assert len(reqs) == 3
    engine.cache._store.clear()
    h0, m0 = engine.cache.hits, engine.cache.misses
    _, metrics = engine.adapt(reqs)
    assert metrics["misses"] == 3 and metrics["hits"] == 0
    # 3 requests pad up to the 4-bucket: one compiled program serves them
    assert metrics["buckets"] == [4]
    _, metrics = engine.adapt(reqs)
    assert metrics["hits"] == 3 and metrics["misses"] == 0
    assert engine.cache.hits - h0 == 3
    assert engine.cache.misses - m0 == 3


def test_adapt_matches_harness_states(engine, episode):
    """Bucket padding must not change the answer: engine.adapt == the
    harness's vmapped adapt_states on the unpadded batch."""
    source, ep = episode
    reqs = [AdaptRequest({k: v[i] for k, v in ep.support.items()})
            for i in range(3)]                  # keyless: no cache path
    results, _ = engine.adapt(reqs)
    stacked = engine.harness.adapt_states(
        engine.params, jax.tree.map(jnp.asarray, ep.support))
    for i, res in enumerate(results):
        ref = jax.tree.map(lambda x, i=i: x[i], stacked)
        for a, b in zip(jax.tree.leaves(res), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cached_reconstruction_drift_within_pin(engine, episode):
    """The ISSUE's fidelity pin: query loss of delta-reconstructed adapted
    params within 1e-2 of the full adapted params."""
    source, ep = episode
    reqs = engine.requests_from_episode(source, ep)
    engine.cache._store.clear()
    full, m = engine.adapt(reqs)
    assert m["misses"] == len(reqs)
    rec, m = engine.adapt(reqs)
    assert m["hits"] == len(reqs)
    qry = [{k: v[i] for k, v in ep.query.items()} for i in range(3)]
    drift = np.abs(engine.adapted_loss(full, qry)
                   - engine.adapted_loss(rec, qry))
    assert float(drift.max()) <= 1e-2, f"delta drift {drift} exceeds pin"


def test_duplicate_domains_alias_one_entry(engine):
    """Two requests for the SAME domain share one cache key — that is the
    recurring-user semantics (one resident state per task), so the second
    insert wins and a later lookup returns that state for both."""
    from repro.launch.serve import make_support_source
    source = make_support_source(engine.cfg, P + G, B)
    ep = source.eval_sample(3, seed=5, split="full")    # domains [3, 5, 3]
    doms = np.asarray(ep.domains).tolist()
    assert len(set(doms)) == 2
    reqs = engine.requests_from_episode(source, ep)
    assert reqs[0].key == reqs[2].key
    engine.cache._store.clear()
    _, m = engine.adapt(reqs)
    assert m["misses"] == 3
    assert engine.cache.stats()["residents"] == 2       # aliased pair = 1
    _, m = engine.adapt(reqs)
    assert m["hits"] == 3


def test_decode_shapes_and_phase_metrics(engine, episode):
    _, ep = episode
    prompt = np.asarray(ep.query["tokens"][0])[:, :P]
    tokens, metrics = engine.decode(engine.params, prompt)
    assert tokens.shape == (B, P + G)
    np.testing.assert_array_equal(tokens[:, :P], prompt)
    assert np.all(tokens >= 0) and np.all(tokens < engine.cfg.padded_vocab)
    # the satellite fix: prompt and decode phases are timed separately
    assert metrics["prompt_tok_s"] > 0 and metrics["decode_tok_s"] > 0
    assert metrics["prefill_s"] > 0 and metrics["decode_s"] > 0


def test_decode_greedy_is_deterministic(engine, episode):
    _, ep = episode
    prompt = np.asarray(ep.query["tokens"][0])[:, :P]
    a, _ = engine.decode(engine.params, prompt, seed=0)
    b, _ = engine.decode(engine.params, prompt, seed=1)  # temp=0: seed moot
    np.testing.assert_array_equal(a, b)


def test_decode_rejects_wrong_prompt_shape(engine):
    with pytest.raises(ValueError, match="prompt shape"):
        engine.decode(engine.params, np.zeros((B, P + 1), np.int32))


def test_log_record_is_serve_kind_and_complete(engine):
    """The record must satisfy scripts/check_run_log.py --serve."""
    import json
    rec = json.loads(json.dumps(engine.log_record()))
    assert rec["kind"] == "serve"
    assert {"hits", "misses", "evictions", "residents",
            "compression"} <= set(rec["cache"])
    assert {"p50_us", "p99_us"} <= set(rec["adapt"])
    assert rec["decode"]["prompt_tok_s"] and rec["decode"]["decode_tok_s"]
