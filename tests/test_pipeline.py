"""MetaBatchPipeline: prefetch == sync, ordering, lifecycle, errors, and
the TrainBundle.make_pipeline integration."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import Episode, LMTaskSource, MetaBatchPipeline, SineTaskSource


def _lm_source(**kw):
    args = dict(vocab_size=128, seq_len=8, K=2, tasks_per_agent=2,
                task_batch=2, n_domains=8, seed=0)
    args.update(kw)
    return LMTaskSource(**args)


def test_prefetch_yields_same_sequence_as_sync():
    src = _lm_source()
    with MetaBatchPipeline(src, depth=3) as pre:
        fetched = [next(pre) for _ in range(6)]
    sync = MetaBatchPipeline(src, depth=0)
    for a, b in zip(fetched, (next(sync) for _ in range(6))):
        np.testing.assert_array_equal(a.support["tokens"],
                                      b.support["tokens"])
        np.testing.assert_array_equal(a.query["labels"], b.query["labels"])


def test_pipeline_order_and_start_step():
    src = _lm_source()
    with MetaBatchPipeline(src, depth=2, start_step=10,
                           prepare=lambda ep: ep.step) as pipe:
        assert [next(pipe) for _ in range(4)] == [10, 11, 12, 13]
        assert pipe.step == 14
    sync = MetaBatchPipeline(src, depth=0, start_step=3,
                             prepare=lambda ep: ep.step)
    assert next(sync) == 3


def test_pipeline_prepare_runs_on_producer():
    src = SineTaskSource(K=2, tasks_per_agent=2, shots=3, n_domains=8)
    prepare = lambda ep: jax.device_put((ep.support, ep.query))
    with MetaBatchPipeline(src, depth=2, prepare=prepare) as pipe:
        support, query = next(pipe)
        assert isinstance(support[0], jax.Array)
        assert support[0].shape == (2, 2, 3, 1)


def test_pipeline_worker_error_propagates():
    class Boom:
        K, tasks_per_agent = 1, 1

        def sample(self, step):
            if step >= 2:
                raise RuntimeError("synthetic sampler failure")
            return Episode({"x": np.zeros((1, 1, 1))},
                           {"x": np.zeros((1, 1, 1))}, step=step)

    with MetaBatchPipeline(Boom(), depth=2) as pipe:
        next(pipe); next(pipe)
        with pytest.raises(RuntimeError, match="prefetch worker failed"):
            next(pipe)


def test_pipeline_stop_joins_worker():
    pipe = MetaBatchPipeline(_lm_source(), depth=2)
    next(pipe)
    thread = pipe._thread
    pipe.stop()
    assert thread is not None and not thread.is_alive()
    pipe.stop()                                  # idempotent
    with pytest.raises(StopIteration):           # drained, not a hang
        next(pipe)


def test_pipeline_is_iterator():
    sync = MetaBatchPipeline(_lm_source(), depth=0)
    steps = [ep.step for ep, _ in zip(sync, range(3))]
    assert steps == [0, 1, 2]


# ---------------------------------------------------------------------------
# TrainBundle.make_pipeline: episodes reach the jitted step pre-sharded
# ---------------------------------------------------------------------------

def test_bundle_make_pipeline_end_to_end():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.configs.base import InputShape
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train import make_train_source
    cfg = get_config("qwen2-1.5b").reduced()
    INPUT_SHAPES["pipe_test"] = InputShape("pipe_test", 16, 8, "train")
    try:
        mesh = make_host_mesh()
        with mesh:
            bundle = S.build_train(cfg, mesh, "pipe_test")
            source = make_train_source(cfg, INPUT_SHAPES["pipe_test"],
                                       bundle.K, bundle.T, bundle.tb)
            state = bundle.init_state(seed=0)
            step = jax.jit(bundle.step_fn)
            with bundle.make_pipeline(source, depth=2) as pipe:
                for _ in range(2):
                    batch = next(pipe)
                    assert batch["tokens"].shape == (8, 16)
                    assert isinstance(batch["tokens"], jax.Array)
                    state, metrics = step(state, batch)
            assert bool(jnp.isfinite(metrics["loss"]))
            assert int(state.step) == 2
    finally:
        del INPUT_SHAPES["pipe_test"]


def test_bundle_make_pipeline_rejects_geometry_mismatch():
    from repro.configs import INPUT_SHAPES, get_config
    from repro.configs.base import InputShape
    from repro.launch import steps as S
    from repro.launch.mesh import make_host_mesh
    cfg = get_config("qwen2-1.5b").reduced()
    INPUT_SHAPES["pipe_geo"] = InputShape("pipe_geo", 16, 8, "train")
    try:
        mesh = make_host_mesh()
        with mesh:
            bundle = S.build_train(cfg, mesh, "pipe_geo")
            bad = LMTaskSource(vocab_size=cfg.padded_vocab, seq_len=16,
                               K=bundle.K + 1, tasks_per_agent=bundle.T,
                               task_batch=bundle.tb,
                               n_domains=8 * (bundle.K + 1))
            with pytest.raises(ValueError, match="does not match"):
                bundle.make_pipeline(bad)
            bad_tb = LMTaskSource(vocab_size=cfg.padded_vocab, seq_len=16,
                                  K=bundle.K, tasks_per_agent=bundle.T,
                                  task_batch=bundle.tb + 1,
                                  n_domains=8 * bundle.K)
            with pytest.raises(ValueError, match="does not match"):
                bundle.make_pipeline(bad_tb)
    finally:
        del INPUT_SHAPES["pipe_geo"]
