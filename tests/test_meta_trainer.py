"""End-to-end Dif-MAML trainer behaviour on the paper's toy settings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MetaConfig, init_state, make_meta_step, make_eval_fn
from repro.core import diffusion, topology
from repro.configs import get_config
from repro.data.sine import agent_sine_distributions, stacked_agent_batch, SineTaskDistribution
from repro.models.simple import SineMLP


@pytest.fixture(scope="module")
def sine_setup():
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    return cfg, model


def _run(model, mcfg, steps=60, seed=0, identical_init=True):
    state = init_state(jax.random.key(seed), model.init, mcfg,
                       identical_init=identical_init)
    step = jax.jit(make_meta_step(model.loss_fn, mcfg))
    dists = agent_sine_distributions(mcfg.num_agents, seed=seed)
    for i in range(steps):
        support, query = stacked_agent_batch(dists, mcfg.tasks_per_agent, 10)
        state, metrics = step(state, jax.tree.map(jnp.asarray, support),
                              jax.tree.map(jnp.asarray, query))
    return state, metrics


def _eval_loss(model, params_centroid, n_tasks=50, steps=1, seed=123):
    dist = SineTaskDistribution(seed=seed)    # full amplitude range
    (sx, sy), (qx, qy) = dist.sample_batch(n_tasks, 10)
    ev = make_eval_fn(model.loss_fn, inner_lr=0.01, inner_steps=steps)
    losses = ev(params_centroid, (jnp.asarray(sx), jnp.asarray(sy)),
                (jnp.asarray(qx), jnp.asarray(qy)))
    return np.asarray(losses).mean(axis=0)    # (steps+1,)


def test_dif_maml_learns_sine(sine_setup):
    _, model = sine_setup
    mcfg = MetaConfig(num_agents=6, tasks_per_agent=3, inner_lr=0.01,
                      mode="maml", combine="dense", topology="paper",
                      outer_optimizer="adam", outer_lr=1e-3)
    state, metrics = _run(model, mcfg, steps=80)
    centroid = diffusion.centroid(state.params)
    post = _eval_loss(model, centroid, steps=1)
    zero_model = model.init(jax.random.key(99))
    base = _eval_loss(model, zero_model, steps=1)
    assert post[1] < base[1]          # meta-training helped adaptation
    assert post[1] < post[0]          # one gradient step improves (MAML works)


def test_cooperation_beats_non_cooperation(sine_setup):
    """Paper Fig. 2b: Dif-MAML < non-cooperative on full-range eval tasks —
    each agent only sees 1/6 of the amplitude range, diffusion shares it."""
    _, model = sine_setup
    common = dict(num_agents=6, tasks_per_agent=3, inner_lr=0.01,
                  mode="maml", topology="paper", outer_optimizer="adam",
                  outer_lr=1e-3)
    st_dif, _ = _run(model, MetaConfig(combine="dense", **common), steps=120)
    st_non, _ = _run(model, MetaConfig(combine="none", **common), steps=120)
    dif_c = diffusion.centroid(st_dif.params)
    post_dif = _eval_loss(model, dif_c, steps=1)[1]
    # non-coop: evaluate each agent separately, average (paper's protocol)
    non_losses = []
    for k in range(6):
        pk = jax.tree.map(lambda x: x[k], st_non.params)
        non_losses.append(_eval_loss(model, pk, steps=1)[1])
    assert post_dif < np.mean(non_losses)


def test_dif_matches_centralized_combine(sine_setup):
    """Fully-connected Metropolis == centralized averaging, exactly."""
    _, model = sine_setup
    common = dict(num_agents=4, tasks_per_agent=2, inner_lr=0.01,
                  mode="maml", topology="full", outer_optimizer="sgd",
                  outer_lr=5e-3)
    mcfg_a = MetaConfig(combine="dense", **common)
    mcfg_b = MetaConfig(combine="centralized", **common)
    sa, _ = _run(model, mcfg_a, steps=10, identical_init=True)
    sb, _ = _run(model, mcfg_b, steps=10, identical_init=True)
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_disagreement_decays_then_plateaus(sine_setup):
    """Thm 1: agents cluster — disagreement stays O(μ²) after transient."""
    _, model = sine_setup
    mcfg = MetaConfig(num_agents=6, tasks_per_agent=2, inner_lr=0.01,
                      mode="maml", combine="dense", topology="ring",
                      outer_optimizer="sgd", outer_lr=5e-3)
    state = init_state(jax.random.key(0), model.init, mcfg,
                       identical_init=False)
    step = jax.jit(make_meta_step(model.loss_fn, mcfg))
    dists = agent_sine_distributions(6)
    d0 = float(diffusion.disagreement(state.params))
    ds = []
    for i in range(40):
        support, query = stacked_agent_batch(dists, 2, 10)
        state, metrics = step(state, jax.tree.map(jnp.asarray, support),
                              jax.tree.map(jnp.asarray, query))
        ds.append(float(metrics["disagreement"]))
    assert ds[-1] < 1e-2 * d0          # fast clustering (linear rate)
    assert max(ds[-10:]) < 5e-2 * d0   # stays clustered (O(μ²) ball)


def test_sparse_combine_equals_dense_in_trainer(sine_setup):
    _, model = sine_setup
    common = dict(num_agents=6, tasks_per_agent=2, inner_lr=0.01,
                  mode="maml", topology="ring", outer_optimizer="sgd",
                  outer_lr=5e-3)
    sa, _ = _run(model, MetaConfig(combine="dense", **common), steps=5)
    sb, _ = _run(model, MetaConfig(combine="sparse", **common), steps=5)
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_grad_clip_zero_is_total_clip(sine_setup):
    """Regression: grad_clip=0.0 must clip (norm bound 0 → zero updates),
    not silently disable clipping via truthiness."""
    _, model = sine_setup
    common = dict(num_agents=4, tasks_per_agent=2, inner_lr=0.01,
                  mode="maml", combine="dense", topology="ring",
                  outer_optimizer="sgd", outer_lr=5e-3)
    mcfg0 = MetaConfig(grad_clip=0.0, **common)
    mcfg_none = MetaConfig(grad_clip=None, **common)
    state = init_state(jax.random.key(0), model.init, mcfg0,
                       identical_init=True)
    dists = agent_sine_distributions(4, seed=0)
    support, query = stacked_agent_batch(dists, 2, 10)
    support = jax.tree.map(jnp.asarray, support)
    query = jax.tree.map(jnp.asarray, query)
    s0, _ = jax.jit(make_meta_step(model.loss_fn, mcfg0))(state, support, query)
    sn, _ = jax.jit(make_meta_step(model.loss_fn, mcfg_none))(state, support,
                                                              query)
    # clip=0.0: SGD updates vanish, combine of identical params is identity
    for before, after in zip(jax.tree.leaves(state.params),
                             jax.tree.leaves(s0.params)):
        np.testing.assert_allclose(np.asarray(after), np.asarray(before),
                                   atol=1e-6)
    # unclipped baseline must actually move
    moved = sum(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(sn.params),
                                jax.tree.leaves(state.params)))
    assert moved > 1e-5


def test_grad_clip_finite_bounds_update_norm(sine_setup):
    _, model = sine_setup
    common = dict(num_agents=4, tasks_per_agent=2, inner_lr=0.01,
                  mode="maml", combine="dense", topology="ring",
                  outer_optimizer="sgd", outer_lr=1.0)
    clip = 1e-3
    mcfg = MetaConfig(grad_clip=clip, **common)
    state = init_state(jax.random.key(0), model.init, mcfg,
                       identical_init=True)
    dists = agent_sine_distributions(4, seed=0)
    support, query = stacked_agent_batch(dists, 2, 10)
    s1, _ = jax.jit(make_meta_step(model.loss_fn, mcfg))(
        state, jax.tree.map(jnp.asarray, support),
        jax.tree.map(jnp.asarray, query))
    # per-agent update norm = lr * clipped grad norm <= lr * clip; the
    # combine is an average so it cannot increase the bound
    delta_sq = sum(np.sum((np.asarray(a, np.float64)
                           - np.asarray(b, np.float64)) ** 2)
                   for a, b in zip(jax.tree.leaves(s1.params),
                                   jax.tree.leaves(state.params)))
    assert np.sqrt(delta_sq) <= 4 * clip * 1.0 * (1 + 1e-4)


def test_fomaml_also_learns(sine_setup):
    _, model = sine_setup
    mcfg = MetaConfig(num_agents=4, tasks_per_agent=3, inner_lr=0.01,
                      mode="fomaml", combine="dense", topology="ring",
                      outer_optimizer="adam", outer_lr=1e-3)
    state, _ = _run(model, mcfg, steps=80)
    centroid = diffusion.centroid(state.params)
    post = _eval_loss(model, centroid, steps=1)
    assert post[1] < post[0]


def test_eval_fn_multi_step_adaptation(sine_setup):
    """Fig 2c mechanism: more adaptation steps keep improving."""
    _, model = sine_setup
    mcfg = MetaConfig(num_agents=6, tasks_per_agent=3, inner_lr=0.01,
                      mode="maml", combine="dense", topology="paper",
                      outer_optimizer="adam", outer_lr=1e-3)
    state, _ = _run(model, mcfg, steps=100)
    centroid = diffusion.centroid(state.params)
    curve = _eval_loss(model, centroid, steps=5)
    assert curve[1] < curve[0]
    assert curve[5] <= curve[1] + 1e-3
