"""bf16 combine wire: dtype resolution rules and the u16 wire contract.

The resolution tests run in-process.  The end-to-end test builds the
mesh_sparse combines on a 4-forced-host-device agent mesh in a subprocess
and checks the module-docstring contract in optimized HLO: the bf16 wire
ships as 2-byte u16 collective-permutes (XLA:CPU's float normalization
would silently re-widen raw bf16 permutes to f32 — the bitcast is what
makes the halving real on every backend), totals exactly deg · bf16-shard
bytes, and the mix stays within one bf16 rounding of the f64 reference.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import diffusion


def test_resolve_combine_dtype_follows_outer_dtype():
    assert diffusion.resolve_combine_dtype("bfloat16") == "bfloat16"
    assert diffusion.resolve_combine_dtype("float32") == "float32"


def test_resolve_combine_dtype_override_wins():
    assert diffusion.resolve_combine_dtype(
        "bfloat16", "float32") == "float32"
    assert diffusion.resolve_combine_dtype(
        "float32", "bfloat16") == "bfloat16"


def test_resolve_combine_dtype_rejects_unknown():
    with pytest.raises(ValueError, match="wire format"):
        diffusion.resolve_combine_dtype("bfloat16", "float16")


def test_wire_elem_bytes():
    assert diffusion.wire_elem_bytes("bfloat16") == 2
    assert diffusion.wire_elem_bytes("float32") == 4


def test_make_combine_rejects_unknown_wire():
    import numpy as np
    with pytest.raises(ValueError, match="wire format"):
        diffusion.make_combine("dense", np.eye(2), combine_dtype="f16")


SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "src")
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import diffusion, topology
    from repro.launch.hlo_cost import HloCost

    K = 4
    topo = topology.build_topology("ring", K)
    mesh = compat.make_mesh((K,), ("agent",))
    sh = NamedSharding(mesh, P("agent"))
    rng = np.random.default_rng(0)
    phi = {"w": jax.device_put(
        rng.standard_normal((K, 256)).astype(np.float32), sh)}
    phi = jax.tree.map(lambda x: x.astype(jnp.bfloat16), phi)
    deg = topology.schedule_ir(topo.matrix).degree
    shard = 256 * 2                       # one agent's bf16 leaf block

    fn = jax.jit(diffusion.make_combine(
        "mesh_sparse", topo.matrix, "agent", mesh=mesh,
        combine_dtype="bfloat16"))
    hlo = fn.lower(phi).compile().as_text()
    cp = HloCost(hlo, n_dev=K).collectives()["per_op"]["collective-permute"]
    u16 = cp["by_dtype"].get("u16", 0)
    assert u16 == deg * shard, (u16, deg * shard, cp)
    assert "f32" not in cp["by_dtype"], cp   # normalization didn't re-widen

    out = fn(phi)
    ref = topo.matrix.T @ np.asarray(phi["w"], np.float64)
    err = float(np.max(np.abs(np.asarray(out["w"], np.float64) - ref)))
    assert err < 2 ** -7, err             # one bf16 rounding of O(1) values
    print("BF16_WIRE_OK", u16, err)
""")


def test_mesh_sparse_bf16_wire_is_u16():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    assert "BF16_WIRE_OK" in out.stdout, out.stderr[-2000:]
