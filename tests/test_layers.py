"""Layer-level unit and property tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models.init import materialize


def _cfg(**kw):
    cfg = get_config("qwen2-7b").reduced()
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def test_rmsnorm_unit_scale():
    p = {"scale": jnp.ones(8)}
    x = jax.random.normal(jax.random.key(0), (2, 3, 8)) * 5
    y = L.norm_apply(p, x)
    ms = jnp.mean(y.astype(jnp.float32) ** 2, axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3)


def test_layernorm_zero_mean_unit_var():
    p = {"scale": jnp.ones(8), "bias": jnp.zeros(8)}
    x = jax.random.normal(jax.random.key(0), (4, 8)) * 3 + 2
    y = L.norm_apply(p, x).astype(jnp.float32)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(jnp.var(y, -1), 1.0, rtol=1e-2)


# ---------------------------------------------------------------------------
# rope
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 4, 9, 14, 20])
def test_rope_preserves_norm(seed):
    x = jax.random.normal(jax.random.key(seed), (1, 6, 2, 16))
    pos = jnp.arange(6)[None]
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-4)


def test_rope_relative_position_property():
    """⟨rope(q,i), rope(k,j)⟩ depends only on i−j."""
    d = 32
    q = jax.random.normal(jax.random.key(0), (1, 1, 1, d))
    k = jax.random.normal(jax.random.key(1), (1, 1, 1, d))

    def dot_at(i, j):
        qi = L.rope(q, jnp.array([[i]]), 1e4)
        kj = L.rope(k, jnp.array([[j]]), 1e4)
        return float(jnp.sum(qi * kj))

    assert dot_at(5, 3) == pytest.approx(dot_at(12, 10), rel=1e-4)
    assert dot_at(0, 0) == pytest.approx(dot_at(100, 100), rel=1e-4)


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.key(0), (1, 1, 2, 16))
    y = L.rope(x, jnp.zeros((1, 1)), 1e4)
    np.testing.assert_allclose(y, x, atol=1e-6)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def test_causal_mask_blocks_future():
    cfg = _cfg(attn_q_chunk=None, use_rope=False)
    params = materialize(L.attention_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    pos = jnp.arange(8)[None]
    y1 = L.attention_apply(params, cfg, x, pos, causal=True)
    # perturb the LAST token only: earlier outputs must not change
    x2 = x.at[:, -1].add(1.0)
    y2 = L.attention_apply(params, cfg, x2, pos, causal=True)
    np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], atol=1e-5)
    assert float(jnp.max(jnp.abs(y1[:, -1] - y2[:, -1]))) > 1e-4


def test_sliding_window_limits_receptive_field():
    cfg = _cfg(attn_q_chunk=None, use_rope=False, sliding_window=2)
    params = materialize(L.attention_specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))
    pos = jnp.arange(8)[None]
    y1 = L.attention_apply(params, cfg, x, pos, causal=True)
    x2 = x.at[:, 0].add(10.0)     # outside the window of position 7
    y2 = L.attention_apply(params, cfg, x2, pos, causal=True)
    np.testing.assert_allclose(y1[:, -1], y2[:, -1], atol=1e-4)


def test_gqa_expand_matches_mha_when_equal_heads():
    k = jax.random.normal(jax.random.key(0), (1, 4, 2, 8))
    assert L._expand_kv(k, 2) is k
    ke = L._expand_kv(k, 6)
    assert ke.shape == (1, 4, 6, 8)
    np.testing.assert_array_equal(ke[:, :, 0], ke[:, :, 2])


@pytest.mark.parametrize("q_chunk", [4, 8, None])
def test_sdpa_chunk_invariance(q_chunk):
    q, k, v = [jax.random.normal(jax.random.key(i), (2, 16, 3, 8))
               for i in range(3)]
    full = L.sdpa(q, k, v, 0.35, causal=True, q_chunk=None)
    out = L.sdpa(q, k, v, 0.35, causal=True, q_chunk=q_chunk)
    np.testing.assert_allclose(out, full, atol=1e-5)


# ---------------------------------------------------------------------------
# mamba2 building blocks
# ---------------------------------------------------------------------------

def test_causal_conv_is_causal():
    x = jax.random.normal(jax.random.key(0), (1, 10, 2, 4))
    w = jax.random.normal(jax.random.key(1), (3, 2, 4))
    y1 = L._causal_conv(x, w)
    x2 = x.at[:, 5].add(1.0)
    y2 = L._causal_conv(x2, w)
    np.testing.assert_allclose(y1[:, :5], y2[:, :5], atol=1e-6)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_ssd_scan_chunk_invariance(chunk):
    B, Lq, H, P, N = 1, 16, 2, 4, 8
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, Lq, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Lq, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    Bm = jax.random.normal(ks[3], (B, Lq, H, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, Lq, H, N)) * 0.5
    y1, s1 = L.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    y2, s2 = L.ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(y1, y2, atol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4)


def test_mla_latent_dim_bottleneck():
    """MLA's KV path must flow through the rank-r latent."""
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    specs = L.mla_specs(cfg)
    assert specs["w_dkv"].shape == (cfg.d_model, cfg.kv_lora_rank)
    assert specs["w_uk"].shape[0] == cfg.kv_lora_rank
    assert specs["w_uv"].shape[0] == cfg.kv_lora_rank
