"""MAML meta-gradient correctness (paper eq. 2-4).

Former hypothesis property tests run as seeded parametrize grids so tier-1
collects with no optional dependencies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import maml


def quad_loss(params, batch):
    """Q(w; (H, b)) = ½ wᵀH w − bᵀw  — analytic meta-gradient available."""
    H, b = batch
    w = params["w"]
    return 0.5 * w @ H @ w - b @ w


def _rand_spd(key, n=4):
    M = jax.random.normal(key, (n, n))
    return M @ M.T / n + 0.5 * jnp.eye(n)


@pytest.mark.parametrize("seed", [0, 7, 19, 40])
@pytest.mark.parametrize("alpha", [0.01, 0.07, 0.2])
def test_meta_grad_matches_analytic(seed, alpha):
    """For quadratic loss the exact meta-gradient (eq. 4) is
    (I − αH) ∇Q(w − α∇Q(w)) with ∇Q(w) = Hw − b."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    H = _rand_spd(k1)
    b = jax.random.normal(k2, (4,))
    w = jax.random.normal(k3, (4,))
    params = {"w": w}
    batch = (H, b)
    _, g = maml.meta_grad(quad_loss, params, batch, batch, alpha=alpha)
    gw = H @ w - b
    w_ad = w - alpha * gw
    expected = (jnp.eye(4) - alpha * H) @ (H @ w_ad - b)
    np.testing.assert_allclose(g["w"], expected, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("seed", [0, 3, 8, 13, 20])
def test_fomaml_drops_curvature(seed):
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    H = _rand_spd(k1)
    b = jax.random.normal(k2, (4,))
    w = jax.random.normal(k3, (4,))
    alpha = 0.1
    batch = (H, b)
    _, g = maml.meta_grad(quad_loss, {"w": w}, batch, batch, alpha=alpha,
                          mode="fomaml")
    gw = H @ w - b
    expected = H @ (w - alpha * gw) - b    # no (I − αH) factor
    np.testing.assert_allclose(g["w"], expected, rtol=1e-4, atol=1e-5)


def test_modes_agree_as_alpha_to_zero():
    k = jax.random.key(0)
    H = _rand_spd(k)
    b = jnp.ones(4)
    w = jnp.arange(4.0)
    batch = (H, b)
    for alpha in [1e-3, 1e-5]:
        _, g2 = maml.meta_grad(quad_loss, {"w": w}, batch, batch, alpha=alpha)
        _, g1 = maml.meta_grad(quad_loss, {"w": w}, batch, batch, alpha=alpha,
                               mode="fomaml")
        diff = float(jnp.max(jnp.abs(g2["w"] - g1["w"])))
        assert diff < 50 * alpha  # curvature term is O(α·λmax·‖u‖)


def test_multi_step_inner_adapt_descends():
    H = _rand_spd(jax.random.key(1))
    b = jnp.ones(4)
    batch = (H, b)
    params = {"w": jnp.zeros(4)}
    losses = [float(quad_loss(params, batch))]
    for steps in [1, 3, 10]:
        ad = maml.inner_adapt(quad_loss, params, batch, alpha=0.1, steps=steps)
        losses.append(float(quad_loss(ad, batch)))
    assert losses == sorted(losses, reverse=True)


def test_inner_remat_does_not_change_grad():
    H = _rand_spd(jax.random.key(2))
    b = jnp.ones(4)
    w = jnp.arange(4.0) * 0.3
    batch = (H, b)
    _, g_rm = maml.meta_grad(quad_loss, {"w": w}, batch, batch, alpha=0.1)
    ad_no = maml.inner_adapt(quad_loss, {"w": w}, batch, alpha=0.1, remat=False)
    g_no = jax.grad(lambda p: quad_loss(
        maml.inner_adapt(quad_loss, p, batch, alpha=0.1, remat=False), batch)
    )({"w": w})
    np.testing.assert_allclose(g_rm["w"], g_no["w"], rtol=1e-5)


def test_multi_task_meta_grad_averages():
    H1 = _rand_spd(jax.random.key(3))
    H2 = _rand_spd(jax.random.key(4))
    b = jnp.ones(4)
    w = {"w": jnp.arange(4.0) * 0.1}
    sup = (jnp.stack([H1, H2]), jnp.stack([b, b]))
    _, g_avg = maml.multi_task_meta_grad(quad_loss, w, sup, sup, alpha=0.1)
    _, g1 = maml.meta_grad(quad_loss, w, (H1, b), (H1, b), alpha=0.1)
    _, g2 = maml.meta_grad(quad_loss, w, (H2, b), (H2, b), alpha=0.1)
    np.testing.assert_allclose(g_avg["w"], (g1["w"] + g2["w"]) / 2, rtol=1e-5)


def test_reptile_direction():
    H = _rand_spd(jax.random.key(5))
    b = jnp.ones(4)
    w = {"w": jnp.zeros(4)}
    batch = (H, b)
    _, g = maml.meta_grad(quad_loss, w, batch, batch, alpha=0.1, mode="reptile")
    ad = maml.inner_adapt(quad_loss, w, batch, alpha=0.1, first_order=True)
    np.testing.assert_allclose(g["w"], (w["w"] - ad["w"]) / 0.1, rtol=1e-5)
