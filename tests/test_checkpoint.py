"""Checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              restore_centroid, latest_step)
from repro.core import MetaConfig, init_state
from repro.optim import adam


def _state():
    init_fn = lambda k: {"w": jax.random.normal(k, (3, 4)),
                         "nested": {"b": jnp.zeros(2)}}
    mcfg = MetaConfig(num_agents=3, outer_optimizer="adam")
    return init_state(jax.random.key(0), init_fn, mcfg)


def test_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_picks_max(tmp_path):
    state = _state()
    for s in (1, 10, 5):
        save_checkpoint(str(tmp_path), s, state)
    assert latest_step(str(tmp_path)) == 10


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "none"), _state())


def test_restore_centroid_means_agent_axis(tmp_path):
    """The serve path's entry point: single-agent params = mean over K."""
    state = _state()
    save_checkpoint(str(tmp_path), 3, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                        state.params)
    centroid = restore_centroid(str(tmp_path), like)
    expect = jax.tree.map(lambda x: np.asarray(x).mean(axis=0), state.params)
    for a, b in zip(jax.tree.leaves(centroid), jax.tree.leaves(expect)):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), b, rtol=1e-6)


def test_restore_centroid_bfloat16_checkpoint(tmp_path):
    """bfloat16 leaves round-trip npz as raw bytes — centroid must still
    decode, average, and land in the requested dtype."""
    from repro.core.meta_trainer import TrainState
    params = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(3, 2)}
    state = TrainState(jnp.zeros((), jnp.int32), params, ())
    save_checkpoint(str(tmp_path), 0, state)
    like = {"w": jax.ShapeDtypeStruct((2,), jnp.float32)}
    centroid = restore_centroid(str(tmp_path), like)
    assert centroid["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(centroid["w"]), [2.0, 3.0])


def _bf16_state():
    """bf16 outer storage with fp32 Adam moments — the --outer-dtype
    bfloat16 TrainState layout."""
    init_fn = lambda k: {
        "w": jax.random.normal(k, (3, 4)).astype(jnp.bfloat16),
        "nested": {"b": jnp.zeros(2, jnp.bfloat16)}}
    mcfg = MetaConfig(num_agents=3, outer_optimizer="adam")
    return init_state(jax.random.key(0), init_fn, mcfg)


def _bits(x):
    a = np.atleast_1d(np.asarray(x))
    return a.view(np.uint16 if x.dtype == jnp.bfloat16 else np.uint8)


def test_bfloat16_roundtrip_bit_parity(tmp_path):
    """The npz raw-bytes path must preserve every bf16 bit pattern, and
    the f32 moments must come back untouched alongside them."""
    state = _bf16_state()
    save_checkpoint(str(tmp_path), 2, state)
    restored = restore_checkpoint(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(_bits(a), _bits(b))


def test_restore_centroid_bfloat16_outer_state(tmp_path):
    """Centroid of a bf16 outer state: decode raw bf16, average in f32,
    land back in the requested bf16 dtype."""
    state = _bf16_state()
    save_checkpoint(str(tmp_path), 0, state)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), state.params)
    centroid = restore_centroid(str(tmp_path), like)
    expect = jax.tree.map(
        lambda x: np.asarray(x, np.float32).mean(axis=0).astype(
            jnp.bfloat16), state.params)
    for a, b in zip(jax.tree.leaves(centroid), jax.tree.leaves(expect)):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_array_equal(_bits(a), _bits(b))


def test_bfloat16_save_restore_resume_bit_parity(tmp_path):
    """save → restore → resume must be bit-identical to an uninterrupted
    run: two Adam steps on bf16 params/f32 moments straight through vs.
    checkpointing after the first."""
    from repro.core.meta_trainer import TrainState
    opt = adam(1e-2)

    def advance(state, seed):
        grads = jax.tree.map(
            lambda p: jax.random.normal(
                jax.random.key(seed), p.shape).astype(p.dtype), state.params)
        upd, opt_state = opt.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u, state.params, upd)
        return TrainState(state.step + 1, params, opt_state)

    straight = advance(advance(_bf16_state(), 1), 2)

    interrupted = advance(_bf16_state(), 1)
    save_checkpoint(str(tmp_path), 1, interrupted)
    restored = restore_checkpoint(str(tmp_path), _bf16_state())
    resumed = advance(restored, 2)

    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(resumed)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(_bits(a), _bits(b))


def test_restore_centroid_missing_dir_raises(tmp_path):
    """Serve's first failure mode: a ckpt dir that was never created.
    The error must name the directory."""
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    missing = str(tmp_path / "never_written")
    with pytest.raises(FileNotFoundError, match="does not exist"):
        restore_centroid(missing, like)


def test_restore_centroid_empty_dir_raises(tmp_path):
    """A dir that exists but holds no ckpt_*.npz (e.g. a crashed save
    left only tmp files) must say so, not die on max() of empty."""
    (tmp_path / "stray.txt").write_text("not a checkpoint")
    like = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    with pytest.raises(FileNotFoundError, match="no ckpt_"):
        restore_centroid(str(tmp_path), like)


def test_restore_centroid_missing_step_lists_available(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 3, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                        state.params)
    with pytest.raises(FileNotFoundError, match=r"available steps: \[3\]"):
        restore_centroid(str(tmp_path), like, step=9)


def test_restore_centroid_spec_mismatch_names_leaf(tmp_path):
    """Restoring with a spec from a different arch: the error must name
    the missing leaf and say the checkpoint doesn't match, not KeyError
    on a raw npz key."""
    state = _state()
    save_checkpoint(str(tmp_path), 0, state)
    like = {"not_in_ckpt": jax.ShapeDtypeStruct((2,), jnp.float32)}
    with pytest.raises(KeyError, match="does not match the requested spec"):
        restore_centroid(str(tmp_path), like)


def test_restore_checkpoint_spec_mismatch_names_leaf(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 0, state)
    bad = {"wrong_layout": jnp.zeros(3)}
    with pytest.raises(KeyError, match="does not match the requested spec"):
        restore_checkpoint(str(tmp_path), bad)


def test_restore_centroid_shape_mismatch_raises(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 0, state)
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((9,) + x.shape[2:], x.dtype),
        state.params)
    with pytest.raises(ValueError, match="agent-stacked"):
        restore_centroid(str(tmp_path), like)


def test_shape_mismatch_raises(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 0, state)
    bad = jax.tree.map(
        lambda x: jnp.zeros((5,) + x.shape[1:]) if x.ndim else x, state)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)
