"""Checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.core import MetaConfig, init_state
from repro.optim import adam


def _state():
    init_fn = lambda k: {"w": jax.random.normal(k, (3, 4)),
                         "nested": {"b": jnp.zeros(2)}}
    mcfg = MetaConfig(num_agents=3, outer_optimizer="adam")
    return init_state(jax.random.key(0), init_fn, mcfg)


def test_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_picks_max(tmp_path):
    state = _state()
    for s in (1, 10, 5):
        save_checkpoint(str(tmp_path), s, state)
    assert latest_step(str(tmp_path)) == 10


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "none"), _state())


def test_shape_mismatch_raises(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 0, state)
    bad = jax.tree.map(
        lambda x: jnp.zeros((5,) + x.shape[1:]) if x.ndim else x, state)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)
