"""Superstep driver: lax.scan over C meta-steps == C per-step dispatches.

Acceptance: the C=4 superstep matches the C=1 path step-by-step on the same
seed (states and metrics), and the stacked pipeline feeds it the identical
batch sequence the per-step pipeline produces.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MetaConfig, TopologyConfig, UpdateConfig, init_state, \
    make_meta_step
from repro.data import LMTaskSource, MetaBatchPipeline, SineTaskSource
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models.simple import SineMLP


def _assert_state_close(a, b, atol=1e-6):
    assert int(a.step) == int(b.step)
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


def test_superstep_c4_matches_c1_step_by_step():
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    K, C, n = 4, 4, 8
    mcfg = MetaConfig(num_agents=K, tasks_per_agent=2, inner_lr=0.01,
                      outer_optimizer="sgd", outer_lr=5e-3,
                      update_config=UpdateConfig(strategy="atc"),
                      topology_config=TopologyConfig(graph="ring",
                                                     schedule="gossip",
                                                     seed=0))
    meta = make_meta_step(model.loss_fn, mcfg)
    step_fn = lambda st, batch: meta(st, batch["support"], batch["query"])
    source = SineTaskSource(K=K, tasks_per_agent=2, shots=5, seed=0)
    batches = []
    for i in range(n):
        ep = source.sample(i)
        batches.append({"support": jax.tree.map(jnp.asarray, ep.support),
                        "query": jax.tree.map(jnp.asarray, ep.query)})

    # C=1 reference: one dispatch (and one metric fetch) per step
    s1 = init_state(jax.random.key(0), model.init, mcfg)
    one = jax.jit(step_fn)
    losses1 = []
    for b in batches:
        s1, m = one(s1, b)
        losses1.append(float(m["loss"]))

    # C=4 superstep: two dispatches, metrics stacked (C,) on device
    s4 = init_state(jax.random.key(0), model.init, mcfg)
    superstep = jax.jit(S.make_superstep(step_fn))
    losses4 = []
    for d in range(n // C):
        chunk = batches[d * C:(d + 1) * C]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *chunk)
        s4, ms = superstep(s4, stacked)
        assert ms["loss"].shape == (C,)
        assert ms["disagreement"].shape == (C,)
        losses4.extend(np.asarray(ms["loss"]).tolist())

    _assert_state_close(s1, s4)
    np.testing.assert_allclose(losses1, losses4, atol=1e-6)


def test_pipeline_stack_groups_without_reordering():
    src = SineTaskSource(K=2, tasks_per_agent=2, shots=3, seed=0)
    with MetaBatchPipeline(src, depth=2, stack=3,
                           prepare=lambda eps: [e.step for e in eps]) as pipe:
        groups = [next(pipe) for _ in range(3)]
        assert pipe.step == 9
    assert groups == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    sync = MetaBatchPipeline(src, depth=0, stack=2, start_step=4,
                             prepare=lambda eps: [e.step for e in eps])
    assert next(sync) == [4, 5]


def _tiny_bundle():
    from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape
    cfg = ArchConfig(name="superstep-test", arch_type="dense", num_layers=1,
                     d_model=16, num_heads=2, num_kv_heads=2, head_dim=8,
                     d_ff=32, vocab_size=64, meta_mode="fomaml",
                     topology="ring", outer_optimizer="adam",
                     dtype="float32", remat=False, attn_q_chunk=None,
                     meta_tasks=2)
    INPUT_SHAPES["superstep_test"] = InputShape("superstep_test", 8, 8,
                                                "train")
    mesh = make_host_mesh(data=1)
    return cfg, mesh, "superstep_test"


def test_bundle_stacked_pipeline_and_superstep_match_per_step():
    cfg, mesh, shape_name = _tiny_bundle()
    C, n = 2, 4
    with mesh:
        bundle = S.build_train(cfg, mesh, shape_name)
        source = LMTaskSource(vocab_size=cfg.padded_vocab, seq_len=8,
                              K=bundle.K, tasks_per_agent=bundle.T,
                              task_batch=bundle.tb, n_domains=4, seed=0)

        # the stacked pipeline yields exactly the per-step batches, grouped
        with bundle.make_pipeline(source, depth=0) as flat_pipe:
            flat = [next(flat_pipe) for _ in range(n)]
        with bundle.make_pipeline(source, depth=0, stack=C) as stacked_pipe:
            stacked = [next(stacked_pipe) for _ in range(n // C)]
        for d, batch in enumerate(stacked):
            for k, v in batch.items():
                assert v.shape[0] == C
                for j in range(C):
                    np.testing.assert_array_equal(np.asarray(v[j]),
                                                  np.asarray(flat[d * C + j][k]))

        # and the scanned superstep reproduces per-step training exactly
        step_fn = jax.jit(bundle.step_fn)
        superstep = jax.jit(S.make_superstep(bundle.step_fn))
        s1 = bundle.init_state(seed=0)
        losses1 = []
        for b in flat:
            s1, m = step_fn(s1, b)
            losses1.append(float(m["loss"]))
        s2 = bundle.init_state(seed=0)
        losses2 = []
        for batch in stacked:
            s2, ms = superstep(s2, batch)
            losses2.extend(np.asarray(ms["loss"]).tolist())
        _assert_state_close(s1, s2, atol=1e-6)
        np.testing.assert_allclose(losses1, losses2, atol=1e-6)
