"""Diffusion combine invariants (paper eq. 6b + Thm 1).

Former hypothesis property tests run as seeded parametrize grids so tier-1
collects with no optional dependencies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diffusion as D
from repro.core import topology as T


def _phi(K, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    return {"w": jax.random.normal(k1, (K, 7, 5)),
            "b": jax.random.normal(k2, (K, 3))}


@pytest.mark.parametrize("K", [2, 3, 7, 16])
@pytest.mark.parametrize("topo", ["ring", "full", "erdos"])
@pytest.mark.parametrize("seed", [0, 11])
def test_combine_preserves_centroid(K, topo, seed):
    """Doubly-stochastic A leaves the network centroid invariant — the
    mechanism behind Thm 2 (the centroid performs unperturbed descent)."""
    A = T.combination_matrix(K, topo, seed=seed) if topo == "erdos" \
        else T.combination_matrix(K, topo)
    phi = _phi(K, seed)
    out = D.dense_combine(jnp.asarray(A), phi)
    for a, b in zip(jax.tree.leaves(D.centroid(phi)),
                    jax.tree.leaves(D.centroid(out))):
        np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("K", [4, 8, 16])
@pytest.mark.parametrize("topo", ["ring", "full"])
def test_sparse_host_equals_dense(K, topo):
    A = T.combination_matrix(K, topo)
    phi = _phi(K, K)
    dense = D.dense_combine(jnp.asarray(A), phi)
    sparse = D.sparse_combine_host(A, phi)
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(sparse)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_centralized_equals_full_graph():
    K = 6
    A = T.combination_matrix(K, "full")
    phi = _phi(K, 1)
    a = D.dense_combine(jnp.asarray(A), phi)
    b = D.centralized_combine(phi)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, atol=1e-5)


def test_no_combine_identity():
    phi = _phi(5)
    out = D.no_combine(phi)
    for x, y in zip(jax.tree.leaves(phi), jax.tree.leaves(out)):
        np.testing.assert_array_equal(x, y)


@pytest.mark.parametrize("K", [2, 4, 7, 12])
@pytest.mark.parametrize("seed", [0, 3, 9])
def test_combine_contracts_disagreement(K, seed):
    """One combine shrinks (1/K)Σ‖w_k − w_c‖² by at least λ₂² (Thm 1)."""
    A = T.combination_matrix(K, "ring")
    lam2 = T.mixing_rate(A)
    phi = _phi(K, seed)
    before = float(D.disagreement(phi))
    after = float(D.disagreement(D.dense_combine(jnp.asarray(A), phi)))
    # f32 slack: near-1 λ₂ (large ring K) puts `after` within float error
    # of the bound itself
    assert after <= lam2 ** 2 * before * (1 + 1e-5) + 1e-5


def test_atc_vs_cta_differ_but_share_centroid_update():
    K = 4
    A = jnp.asarray(T.combination_matrix(K, "ring"))
    params = _phi(K, 2)
    updates = jax.tree.map(lambda x: 0.1 * jnp.ones_like(x), params)
    combine = lambda p: D.dense_combine(A, p)
    atc = D.atc_step(params, updates, combine)
    cta = D.cta_step(params, updates, combine)
    c_atc = D.centroid(atc)
    c_cta = D.centroid(cta)
    for a, b in zip(jax.tree.leaves(c_atc), jax.tree.leaves(c_cta)):
        np.testing.assert_allclose(a, b, atol=1e-5)   # same centroid motion
    diff = sum(float(jnp.sum(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(atc), jax.tree.leaves(cta)))
    assert diff > 1e-6                                 # but different iterates


def test_disagreement_zero_for_identical_agents():
    phi = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), _phi(6))
    assert float(D.disagreement(phi)) < 1e-10


def test_make_combine_factory():
    K = 4
    A = T.combination_matrix(K, "ring")
    for name in ["dense", "sparse_host", "centralized", "none"]:
        fn = D.make_combine(name, A=A)
        out = fn(_phi(K))
        assert jax.tree.structure(out) == jax.tree.structure(_phi(K))
    with pytest.raises(ValueError):
        D.make_combine("bogus", A=A)
