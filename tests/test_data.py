"""Task-distribution substrates."""
import numpy as np
import pytest

from repro.data.sine import (SineTaskDistribution, agent_sine_distributions,
                             stacked_agent_batch, AMP_LO, AMP_HI)
from repro.data.fewshot import FewShotSampler
from repro.data.lm_tasks import LMTaskSampler


def test_sine_shapes_and_ranges():
    d = SineTaskDistribution(seed=1)
    (sx, sy), (qx, qy) = d.sample_batch(7, 10)
    assert sx.shape == (7, 10, 1) and qy.shape == (7, 10, 1)
    assert np.all(np.abs(sy) <= AMP_HI)
    # support and query are disjoint draws (the paper's X_in / X_o)
    assert not np.allclose(sx, qx)


def test_agent_amplitude_partition():
    """Paper §4.1: [0.1, 5.0] evenly split across K agents."""
    K = 6
    dists = agent_sine_distributions(K)
    edges = np.linspace(AMP_LO, AMP_HI, K + 1)
    for k, d in enumerate(dists):
        assert d.amp_lo == pytest.approx(edges[k])
        assert d.amp_hi == pytest.approx(edges[k + 1])
    (sx, sy), _ = dists[0].sample_batch(100, 5)
    assert np.max(np.abs(sy)) <= edges[1] + 1e-6


def test_stacked_agent_batch_layout():
    dists = agent_sine_distributions(4)
    (sx, sy), (qx, qy) = stacked_agent_batch(dists, 3, 10)
    assert sx.shape == (4, 3, 10, 1)
    assert qy.shape == (4, 3, 10, 1)


def test_fewshot_episode_structure():
    s = FewShotSampler(n_classes=50, n_way=5, k_shot=1, n_query=4, seed=0)
    (sx, sy), (qx, qy) = s.sample(6)
    assert sx.shape == (6, 5, s.dim) and sy.shape == (6, 5)
    assert qx.shape == (6, 20, s.dim)
    for t in range(6):
        assert set(sy[t].tolist()) == set(range(5))


def test_fewshot_meta_split_disjoint():
    s = FewShotSampler(n_classes=50, train_fraction=0.8)
    assert len(set(s._train_classes) & set(s._test_classes)) == 0


def test_fewshot_agents_layout():
    s = FewShotSampler(n_classes=60)
    (sx, sy), (qx, qy) = s.sample_agents(K=3, tasks_per_agent=2)
    assert sx.shape[:2] == (3, 2)


def test_lm_tasks_deterministic_per_domain():
    s = LMTaskSampler(vocab_size=1024, seq_len=32, seed=7)
    a = s.sample_task(3, batch=4, seed=11)
    b = s.sample_task(3, batch=4, seed=11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_lm_tasks_domains_differ():
    s = LMTaskSampler(vocab_size=1024, seq_len=64)
    a = s.sample_task(0, 2, seed=5)["tokens"]
    b = s.sample_task(1, 2, seed=5)["tokens"]
    assert not np.array_equal(a, b)


def test_lm_tasks_agent_stacking():
    s = LMTaskSampler(vocab_size=512, seq_len=16, n_domains=8)
    sup, qry = s.sample_agents(K=4, tasks_per_agent=2, task_batch=3)
    assert sup["tokens"].shape == (4, 2, 3, 16)
    assert qry["labels"].shape == (4, 2, 3, 16)
    assert sup["tokens"].max() < 512
