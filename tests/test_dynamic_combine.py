"""ScheduleIR lowering + dynamic sparse combine backends.

Acceptance: the sparse_dynamic family reproduces the dense step-indexed
einsum for every dynamic schedule kind on ring/full with ragged mixed-dtype
pytrees, and the selection/resolution rules prefer the sparse lowering over
the dense stacked fallback whenever the offset union is sparse.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diffusion as D
from repro.core import topology as T

K = 8

SCHED_KW = {"link_failure": dict(p=0.3, period=7, seed=1),
            "gossip": dict(period=5, seed=2),
            "round_robin": {}}


def _schedule(kind, topo_name, K=K):
    topo = T.build_topology(topo_name, K)
    return T.make_schedule(kind, topo, **SCHED_KW.get(kind, {}))


def _ragged_phi(K, seed=0):
    """Ragged sizes, mixed dtype — nothing lane-aligned."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return {"w": jax.random.normal(k1, (K, 7, 5)),
            "b": jax.random.normal(k2, (K, 3)).astype(jnp.bfloat16),
            "scale": jax.random.normal(k3, (K, 17))}


def _assert_tree_close(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        tol = 2e-2 if x.dtype == jnp.bfloat16 else 1e-6
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=tol)


# ---------------------------------------------------------------------------
# ScheduleIR: exact decomposition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["static", "link_failure", "gossip",
                                  "round_robin"])
@pytest.mark.parametrize("topo", ["ring", "full"])
def test_ir_reconstructs_stack_exactly(kind, topo):
    sched = _schedule(kind, topo)
    ir = sched.ir()
    np.testing.assert_array_equal(ir.stacked(), sched.matrices)
    assert ir.period == sched.period
    assert ir.K == K


def test_ir_offsets_are_the_static_graphs_union():
    """Dynamic kinds never activate an edge outside the static graph, so
    the offset union (= the fixed ppermute rounds) is the static set:
    deg 2 on the ring regardless of the schedule's randomness."""
    for kind in ["link_failure", "gossip", "round_robin"]:
        ir = _schedule(kind, "ring").ir()
        assert set(ir.offsets) <= {1, K - 1}
        assert ir.degree <= 2
    assert _schedule("round_robin", "full").ir().degree == K - 1


def test_ir_keeps_offsets_with_negative_weights():
    """Negative off-diagonal weights (legal in e.g. accelerated consensus
    matrices) must keep their offset — dropping them would make the sparse
    lowering silently diverge from the dense einsum."""
    A = np.eye(4)
    for k in range(4):
        A[(k - 1) % 4, k] = -0.1          # offset 1, all-negative weights
        A[k, k] = 1.1
    ir = T.schedule_ir(A)
    assert 1 in ir.offsets
    np.testing.assert_array_equal(ir.matrix_at(0), A)
    phi = _ragged_phi(4)
    _assert_tree_close(D.make_combine("sparse_host_dynamic", A=A)(phi),
                       D.dense_combine(jnp.asarray(A), phi))


def test_schedule_ir_accepts_single_matrix():
    A = T.combination_matrix(K, "ring")
    ir = T.schedule_ir(A)
    assert ir.period == 1 and ir.degree == 2
    np.testing.assert_array_equal(ir.matrix_at(0), A)


# ---------------------------------------------------------------------------
# Parity: sparse_host_dynamic == dense stacked, every kind × topology
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["link_failure", "gossip", "round_robin"])
@pytest.mark.parametrize("topo", ["ring", "full"])
def test_sparse_host_dynamic_matches_dense_stacked(kind, topo):
    sched = _schedule(kind, topo)
    stack = sched.matrices
    phi = _ragged_phi(K, seed=3)
    dense = D.make_combine("dense", A=stack)
    dyn = jax.jit(D.make_combine("sparse_host_dynamic", A=stack))
    for step in [0, 2, sched.period, 2 * sched.period + 1]:   # incl. wraps
        _assert_tree_close(dense(phi, jnp.int32(step)),
                           dyn(phi, jnp.int32(step)))


def test_sparse_host_dynamic_accepts_ir_and_static_matrix():
    sched = _schedule("round_robin", "ring")
    phi = _ragged_phi(K, seed=4)
    via_ir = D.make_combine("sparse_host_dynamic", A=sched.ir())
    via_stack = D.make_combine("sparse_host_dynamic", A=sched.matrices)
    _assert_tree_close(via_ir(phi, jnp.int32(1)),
                       via_stack(phi, jnp.int32(1)))
    # a static (K, K) matrix is the S=1 degenerate: step optional
    A = T.combination_matrix(K, "ring")
    static = D.make_combine("sparse_host_dynamic", A=A)
    _assert_tree_close(static(phi), D.sparse_combine_host(A, phi))


def test_dynamic_combine_requires_step_when_periodic():
    sched = _schedule("gossip", "ring")
    fn = D.make_combine("sparse_host_dynamic", A=sched.matrices)
    with pytest.raises(ValueError, match="step"):
        fn(_ragged_phi(K))


# ---------------------------------------------------------------------------
# Selection / resolution rules
# ---------------------------------------------------------------------------

def test_select_backend_prefers_sparse_dynamic_for_stacked():
    ring = _schedule("link_failure", "ring").matrices
    assert D.select_backend(ring) == "sparse_host_dynamic"
    # dense offset union (full graph): the step-indexed einsum stays
    full = _schedule("link_failure", "full").matrices
    assert D.select_backend(full) == "dense"
    # live mesh with one agent per shard upgrades to the mesh backend
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((K, 2), ("data", "model"))
    assert D.select_backend(ring, mesh=mesh,
                            axis_name="data") == "mesh_sparse_dynamic"
    assert D.select_backend(ring, mesh=mesh,
                            axis_name="model") == "sparse_host_dynamic"


def test_resolve_upgrades_static_sparse_to_dynamic_sibling():
    stack = _schedule("gossip", "ring").matrices
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # upgrade is silent
        assert D.resolve_schedule_backend("sparse", stack) == "sparse_dynamic"
        assert (D.resolve_schedule_backend("sparse_host", stack)
                == "sparse_host_dynamic")
        assert (D.resolve_schedule_backend("mesh_sparse", stack)
                == "mesh_sparse_dynamic")
        # matrix-free and already-capable backends pass through
        assert D.resolve_schedule_backend("none", stack) == "none"
        assert (D.resolve_schedule_backend("sparse_host_dynamic", stack)
                == "sparse_host_dynamic")
    # a static matrix never rewrites the choice
    A = T.combination_matrix(K, "ring")
    assert D.resolve_schedule_backend("sparse_host", A) == "sparse_host"


def test_reject_stacked_points_at_dynamic_sibling():
    stack = _schedule("round_robin", "ring").matrices
    for name in ["sparse_host", "sparse", "mesh_sparse"]:
        with pytest.raises(ValueError, match=f"{name}_dynamic|dynamic"):
            D.make_combine(name, A=stack, axis_name="data", mesh="unused")


def test_combine_wire_bytes_dynamic():
    stack = _schedule("link_failure", "ring").matrices
    mb = 1000
    assert D.combine_wire_bytes(stack, "sparse_host_dynamic", mb) == 2 * mb
    assert D.combine_wire_bytes(stack, "mesh_sparse_dynamic", mb) == 2 * mb
    assert D.combine_wire_bytes(stack, "dense", mb) == (K - 1) * mb


def test_mesh_sparse_dynamic_validates_agent_extent():
    from repro.compat import abstract_mesh
    stack = _schedule("gossip", "ring").matrices
    mesh = abstract_mesh((4, 2), ("data", "model"))   # extent 4 != K=8
    with pytest.raises(ValueError, match="one agent per shard"):
        D.make_combine("mesh_sparse_dynamic", A=stack, mesh=mesh,
                       axis_name="data")


# ---------------------------------------------------------------------------
# Trainer integration: dynamic sparse backend == dense backend, end to end
# ---------------------------------------------------------------------------

def test_trainer_sparse_dynamic_matches_dense_backend():
    from repro.configs import get_config
    from repro.core import (MetaConfig, TopologyConfig, UpdateConfig,
                            init_state, make_meta_step)
    from repro.data.sine import agent_sine_distributions, stacked_agent_batch
    from repro.models.simple import SineMLP

    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    Ka = 6

    def run(backend, steps=6):
        mcfg = MetaConfig(
            num_agents=Ka, tasks_per_agent=2, inner_lr=0.01,
            outer_optimizer="sgd", outer_lr=5e-3,
            update_config=UpdateConfig(strategy="atc", backend=backend),
            topology_config=TopologyConfig(graph="ring",
                                           schedule="link_failure",
                                           link_failure_p=0.3, seed=0))
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=False)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        dists = agent_sine_distributions(Ka)
        for _ in range(steps):
            sup, qry = stacked_agent_batch(dists, 2, 10)
            state, metrics = step(state, jax.tree.map(jnp.asarray, sup),
                                  jax.tree.map(jnp.asarray, qry))
        return state

    # 'sparse_host' upgrades to 'sparse_host_dynamic' via
    # resolve_schedule_backend inside make_meta_step
    sa = run("dense")
    sb = run("sparse_host")
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
