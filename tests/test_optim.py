"""Optimizer math + sharding-friendly state layout."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam, sgd, momentum, clip_by_global_norm, get_optimizer


def test_sgd_is_scaled_negative_gradient():
    opt = sgd(0.1)
    g = {"w": jnp.ones(3)}
    u, _ = opt.update(g, opt.init(g), g)
    np.testing.assert_allclose(u["w"], -0.1 * jnp.ones(3))


def test_adam_reference_sequence():
    """Cross-check against a hand-rolled Adam on a scalar."""
    lr, b1, b2, eps = 0.01, 0.9, 0.999, 1e-8
    opt = adam(lr, b1, b2, eps)
    p = {"w": jnp.asarray(1.0)}
    state = opt.init(p)
    m = v = 0.0
    w = 1.0
    for t in range(1, 6):
        g = {"w": jnp.asarray(2.0 * w)}          # d/dw w²
        u, state = opt.update(g, state, p)
        p = jax.tree.map(lambda a, b: a + b, p, u)
        m = b1 * m + (1 - b1) * (2 * w)
        v = b2 * v + (1 - b2) * (2 * w) ** 2
        mh, vh = m / (1 - b1 ** t), v / (1 - b2 ** t)
        w = w - lr * mh / (np.sqrt(vh) + eps)
        np.testing.assert_allclose(float(p["w"]), w, rtol=1e-5)


def test_momentum_accumulates():
    opt = momentum(1.0, beta=0.5)
    g = {"w": jnp.asarray(1.0)}
    s = opt.init(g)
    u1, s = opt.update(g, s, g)
    u2, s = opt.update(g, s, g)
    assert float(u2["w"]) == -1.5   # v = 0.5*1 + 1


def test_adam_preserves_agent_leading_axis():
    """Per-agent moments: state leaves mirror the (K, ...) param layout."""
    opt = adam(1e-3)
    params = {"w": jnp.ones((4, 8))}
    state = opt.init(params)
    assert state.mu["w"].shape == (4, 8)
    g = {"w": jnp.ones((4, 8))}
    u, state = opt.update(g, state, params)
    assert u["w"].shape == (4, 8)
    # agents with identical grads stay identical
    assert float(jnp.max(jnp.abs(u["w"] - u["w"][:1]))) == 0.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    norm = float(jnp.sqrt(jnp.sum(9.0 * jnp.ones(4)) + jnp.sum(16.0 * jnp.ones(9))))
    clipped = clip_by_global_norm(g, 1.0)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped))))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)
    unclipped = clip_by_global_norm(g, norm * 2)
    np.testing.assert_allclose(unclipped["a"], g["a"])


def test_get_optimizer_registry():
    for name in ["sgd", "momentum", "adam", "adamw"]:
        opt = get_optimizer(name, 1e-3)
        p = {"w": jnp.ones(2)}
        u, _ = opt.update(p, opt.init(p), p)
        assert u["w"].shape == (2,)
