"""The shared adaptation-at-evaluation-time engine (repro.eval).

Parity: the harness's measured losses must BIT-match the trainer's own
forward path (``maml.meta_loss``) — eval and train adapt through the same
``maml.inner_adapt``, so any drift is a bug, not a tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import MetaConfig, diffusion, init_state, make_eval_fn, maml
from repro.data import LMTaskSource, SineTaskSource
from repro.eval import EvalHarness
from repro.eval.harness import split_seed
from repro.models.simple import SineMLP


@pytest.fixture(scope="module")
def sine_model():
    cfg = get_config("sine_mlp")
    return SineMLP(cfg)


@pytest.fixture(scope="module")
def sine_source():
    return SineTaskSource(K=4, tasks_per_agent=3, shots=6, n_domains=16,
                          holdout_domains=4, seed=0)


def _eval_batch(source, n_tasks=8, seed=5, split=None):
    ep = source.eval_sample(n_tasks, seed=seed, split=split)
    return (jax.tree.map(jnp.asarray, ep.support),
            jax.tree.map(jnp.asarray, ep.query))


def test_harness_bitmatches_meta_loss_fomaml(sine_model, sine_source):
    """Zero-shot = plain query loss; one-step = meta_loss('fomaml', steps=1).
    Exact equality: the harness IS the trainer's forward path."""
    model = sine_model
    params = model.init(jax.random.key(0))
    esup, eqry = _eval_batch(sine_source)
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=1)
    curves = np.asarray(h.curves(params, esup, eqry))      # (tasks, 2)

    per_task = jax.jit(jax.vmap(lambda s, q: (
        model.loss_fn(params, q),
        maml.meta_loss(model.loss_fn, params, s, q, alpha=0.01, steps=1,
                       mode="fomaml"))))
    l0, l1 = (np.asarray(x) for x in per_task(esup, eqry))
    np.testing.assert_array_equal(curves[:, 0], l0)
    np.testing.assert_array_equal(curves[:, 1], l1)


def test_harness_multi_step_matches_meta_loss(sine_model, sine_source):
    """Curve index s = meta_loss after s inner steps, for every s."""
    model = sine_model
    params = model.init(jax.random.key(1))
    esup, eqry = _eval_batch(sine_source, n_tasks=4)
    steps = 3
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=steps)
    curves = np.asarray(h.curves(params, esup, eqry))
    for s in range(1, steps + 1):
        ml = jax.jit(jax.vmap(lambda sup, q: maml.meta_loss(
            model.loss_fn, params, sup, q, alpha=0.01, steps=s,
            mode="fomaml")))
        np.testing.assert_allclose(curves[:, s], np.asarray(ml(esup, eqry)),
                                   rtol=1e-6)


def test_make_eval_fn_is_harness_curves(sine_model, sine_source):
    """The compatibility wrapper returns exactly the harness primitive."""
    model = sine_model
    params = model.init(jax.random.key(2))
    esup, eqry = _eval_batch(sine_source)
    ev = make_eval_fn(model.loss_fn, inner_lr=0.01, inner_steps=2)
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=2)
    np.testing.assert_array_equal(np.asarray(ev(params, esup, eqry)),
                                  np.asarray(h.curves(params, esup, eqry)))


def test_evaluate_full_protocol_on_trainstate(sine_model, sine_source):
    """TrainState in → both splits, centroid + per-agent curves, gap and
    disagreement out; the JSONL record is complete and serializable."""
    import json
    model = sine_model
    mcfg = MetaConfig(num_agents=4, tasks_per_agent=3)
    state = init_state(jax.random.key(0), model.init, mcfg)
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=2)
    report = h.evaluate(state, sine_source, n_tasks=6, seed=3)
    assert set(report.splits) == {"recurring", "unseen"}
    for s in report.splits.values():
        assert s.centroid_curve.shape == (3,)
        assert s.agent_curve.shape == (3,)
        assert s.n_tasks == 6
    assert report.disagreement > 0        # independent inits disagree
    rec = json.loads(json.dumps(report.to_record()))
    assert rec["step"] == 0
    assert {"recurring", "unseen"} <= set(rec["splits"])
    assert rec["generalization_gap"] == pytest.approx(
        report.splits["unseen"].centroid_curve[-1]
        - report.splits["recurring"].centroid_curve[-1])


def test_evaluate_centroid_equals_identical_agents(sine_model, sine_source):
    """With identical per-agent params the agent curve equals the centroid
    curve — the per-agent path measures the same engine."""
    model = sine_model
    mcfg = MetaConfig(num_agents=3, tasks_per_agent=2)
    state = init_state(jax.random.key(4), model.init, mcfg,
                       identical_init=True)
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=1)
    report = h.evaluate(state, sine_source, n_tasks=5, seed=9)
    for s in report.splits.values():
        np.testing.assert_allclose(s.agent_curve, s.centroid_curve,
                                   rtol=1e-6)
    assert report.disagreement < 1e-12


def test_evaluate_accepts_bare_agent_params(sine_model, sine_source):
    model = sine_model
    mcfg = MetaConfig(num_agents=2, tasks_per_agent=2)
    state = init_state(jax.random.key(5), model.init, mcfg)
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=1)
    via_state = h.evaluate(state, sine_source, n_tasks=4, seed=1)
    via_params = h.evaluate(state.params, sine_source, n_tasks=4, seed=1)
    assert via_params.step is None
    np.testing.assert_array_equal(
        via_state.splits["unseen"].centroid_curve,
        via_params.splits["unseen"].centroid_curve)


def test_split_seed_decorrelates_and_is_deterministic():
    """Each split derives its own deterministic seed from the base seed;
    identical per-split seeds were the correlated-draw bug (recurring and
    unseen sharing one RNG stream narrows the measured gap)."""
    assert split_seed(7, "recurring") == split_seed(7, "recurring")
    assert split_seed(7, "recurring") != split_seed(7, "unseen")
    assert split_seed(8, "recurring") != split_seed(7, "recurring")
    assert split_seed(None, "unseen") is None
    assert 0 <= split_seed(7, "unseen") <= 0x7FFF_FFFF


def test_evaluate_passes_per_split_seeds(sine_model, sine_source):
    """Regression: evaluate must NOT hand the same seed to every split's
    eval_sample — each split gets its split_seed-derived stream."""
    model = sine_model
    mcfg = MetaConfig(num_agents=2, tasks_per_agent=2)
    state = init_state(jax.random.key(6), model.init, mcfg)
    seen = {}

    class Recorder:
        def eval_sample(self, n_tasks, seed=None, split=None, **kw):
            seen[split] = seed
            return sine_source.eval_sample(n_tasks, seed=seed, split=split,
                                           **kw)

    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=1)
    h.evaluate(state, Recorder(), n_tasks=4, seed=11)
    assert set(seen) == {"recurring", "unseen"}
    assert seen["recurring"] == split_seed(11, "recurring")
    assert seen["unseen"] == split_seed(11, "unseen")
    assert seen["recurring"] != seen["unseen"]


def test_adapt_states_matches_inner_adapt(sine_model, sine_source):
    """The serve tier's batched-adapt primitive: vmapped states must
    bit-match per-task inner_adapt."""
    model = sine_model
    params = model.init(jax.random.key(7))
    esup, _ = _eval_batch(sine_source, n_tasks=3)
    h = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=2)
    stacked = h.adapt_states(params, esup)
    for i in range(3):
        one_sup = jax.tree.map(lambda x, i=i: x[i], esup)
        ref = maml.inner_adapt(model.loss_fn, params, one_sup, alpha=0.01,
                               steps=2, first_order=True)
        got = jax.tree.map(lambda x, i=i: x[i], stacked)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_harness_on_lm_source_task_batch_layout():
    """Dict-batch (LM) episodes flow through the same engine."""
    src = LMTaskSource(vocab_size=64, seq_len=8, K=2, tasks_per_agent=2,
                       task_batch=2, n_domains=8, holdout_domains=2, seed=0)

    def loss_fn(params, batch):
        pred = batch["tokens"].astype(jnp.float32) * params["s"]
        return jnp.mean((pred - batch["labels"].astype(jnp.float32)) ** 2)

    params = {"s": jnp.asarray(0.1)}
    h = EvalHarness(loss_fn, inner_lr=0.001, inner_steps=2)
    ep = src.eval_sample(5, seed=2, split="unseen")
    curves = h.curves(params, jax.tree.map(jnp.asarray, ep.support),
                      jax.tree.map(jnp.asarray, ep.query))
    assert curves.shape == (5, 3)
    assert bool(jnp.all(jnp.isfinite(curves)))
