"""The trip-count-aware HLO cost model (launch/hlo_cost.py)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis
from repro.launch.hlo_cost import HloCost, corrected_costs


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_multiply_by_trip_count():
    W = jnp.ones((256, 256))

    def scan_n(x):
        x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=7)
        return x

    txt = _compile_text(scan_n, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    c = HloCost(txt)
    assert c.flops() == pytest.approx(7 * 2 * 256 ** 3, rel=0.01)
    # XLA's own analysis undercounts (counts the body once) — that is the
    # reason this module exists
    raw = cost_analysis(jax.jit(scan_n).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile())
    assert raw["flops"] < c.flops() / 2


def test_plain_matmul_matches_xla():
    W = jnp.ones((128, 128))
    f = lambda x: x @ W
    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = _compile_text(f, spec)
    c = HloCost(txt)
    raw = cost_analysis(jax.jit(f).lower(spec).compile())
    assert c.flops() == pytest.approx(raw["flops"], rel=0.01)


def test_nested_scans_multiply():
    W = jnp.ones((64, 64))

    def inner(x):
        x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=3)
        return x

    def outer(x):
        x, _ = jax.lax.scan(lambda h, _: (inner(h), None), x, None, length=5)
        return x

    txt = _compile_text(outer, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    c = HloCost(txt)
    assert c.flops() == pytest.approx(15 * 2 * 64 ** 3, rel=0.05)


def test_bytes_scale_with_trip_count():
    W = jnp.ones((256, 256))

    def scan_n(n):
        def f(x):
            x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=n)
            return x
        return f

    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b2 = HloCost(_compile_text(scan_n(2), spec)).bytes_accessed()
    b8 = HloCost(_compile_text(scan_n(8), spec)).bytes_accessed()
    assert 2.5 < b8 / b2 < 5.0      # ~4× (plus fixed entry-block cost)


def test_corrected_costs_api():
    f = lambda x: jnp.sin(x) @ jnp.ones((32, 32))
    txt = _compile_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    out = corrected_costs(txt)
    assert out["flops"] > 0 and out["bytes"] > 0


# ---------------------------------------------------------------------------
# Dynamic-schedule combine: collective bytes scale with deg, not K
# (regression alongside the combine_every conditional-combine test in
# test_update.py — both pin communication cost at the HLO level)
# ---------------------------------------------------------------------------

_DYNAMIC_BYTES_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import diffusion, topology
    from repro.launch.hlo_cost import HloCost

    K, M = 8, 2048
    mesh = compat.make_mesh((K,), ("data",))
    phi = {"w": jax.random.normal(jax.random.key(0), (K, M), jnp.float32)}
    phi_sh = {"w": jax.device_put(phi["w"], NamedSharding(mesh, P("data", None)))}
    step = jnp.zeros((), jnp.int32)
    out = {"shard_bytes": M * 4}
    with mesh:
        for topo_name in ["ring", "full"]:
            topo = topology.build_topology(topo_name, K)
            sched = topology.make_schedule("link_failure", topo, p=0.3,
                                           period=8, seed=0)
            dyn = jax.jit(diffusion.make_combine(
                "mesh_sparse_dynamic", A=sched.matrices, mesh=mesh,
                axis_name="data", in_specs={"w": P("data", None)}))
            dense = jax.jit(diffusion.make_combine("dense", A=sched.matrices))
            rec = {"deg": sched.ir().degree}
            for name, fn in [("sparse", dyn), ("dense", dense)]:
                txt = fn.lower(phi_sh, step).compile().as_text()
                coll = HloCost(txt, n_dev=K).collectives()
                rec[name + "_bytes"] = coll["total_bytes"]
                rec[name + "_count"] = coll["total_count"]
                rec[name + "_permutes"] = coll["per_op"].get(
                    "collective-permute", {}).get("count", 0)
                if topo_name == "ring" and name == "sparse":
                    # bit-parity fixture: legacy agent_combine_check vs the
                    # collective-budget rule, clean + seeded-violation
                    from repro.analysis.rules import LintContext, run_rules
                    from repro.launch.hlo_cost import agent_combine_check
                    deg, par = sched.ir().degree, {}
                    for case, sb in [("ok", M * 4), ("violated", M * 16)]:
                        legacy = agent_combine_check(txt, K, degree=deg,
                                                     shard_bytes=sb)
                        ctx = LintContext(hlo=txt, n_dev=K, K=K, degree=deg,
                                          shard_bytes=sb)
                        rep = run_rules(ctx, only=["collective-budget"])
                        par[case] = {
                            "legacy": legacy,
                            "rule_record": rep.records["collective-budget"],
                            "rule_ok": rep.to_json()["ok"]}
                    out["parity"] = par
            out[topo_name] = rec
    print("HLO_BYTES_JSON:" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def dynamic_bytes_out():
    """One 8-host-device subprocess serving every K=8-ring HLO assertion
    in this module (compiles are the cost; the parsing is free)."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _DYNAMIC_BYTES_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    lines = [l for l in res.stdout.splitlines()
             if l.startswith("HLO_BYTES_JSON:")]
    assert lines, res.stderr[-2000:]
    return json.loads(lines[0][len("HLO_BYTES_JSON:"):])


def test_sparse_dynamic_collective_bytes_scale_with_deg_not_K(
        dynamic_bytes_out):
    """At K=8 the sparse_dynamic combine must move deg permutes of one
    shard each: deg=2 on the ring, deg=7 on the full graph — and the ring
    must stay under the (deg+1)/K bound of the dense-stacked bytes."""
    out = dynamic_bytes_out
    shard = out["shard_bytes"]
    ring, full = out["ring"], out["full"]
    assert (ring["deg"], full["deg"]) == (2, 7)
    # deg collective-permutes of one local shard each — wire scales with
    # the offset-union degree, NOT with K
    assert ring["sparse_permutes"] == 2
    assert full["sparse_permutes"] == 7
    assert ring["sparse_bytes"] == 2 * shard
    assert full["sparse_bytes"] == 7 * shard
    # acceptance bound: ring sparse ≤ (deg+1)/K of the dense-stacked bytes
    assert ring["dense_bytes"] > 0
    assert ring["sparse_bytes"] <= (ring["deg"] + 1) / 8 * ring["dense_bytes"]


def test_collective_budget_rule_bit_parity_on_k8_ring(dynamic_bytes_out):
    """agent_combine_check is now a shim over the collective-budget rule's
    combine_window: on the K=8 ring fixture the legacy record and the
    rule's record must match field-for-field, and their verdicts must
    agree on both the clean and the seeded-violation (shard×4) case."""
    par = dynamic_bytes_out["parity"]
    for case, should_pass in [("ok", True), ("violated", False)]:
        legacy, rule = par[case]["legacy"], par[case]["rule_record"]
        assert legacy == rule, (case, legacy, rule)
        assert legacy["ok"] is should_pass
        assert par[case]["rule_ok"] is should_pass


# ---------------------------------------------------------------------------
# Per-dtype collective accounting (the bf16-wire budget windows filter on it)
# ---------------------------------------------------------------------------

_MIXED_DTYPE_HLO = textwrap.dedent("""
    HloModule mixed

    %add (a: s32[], b: s32[]) -> s32[] {
      %a = s32[] parameter(0)
      %b = s32[] parameter(1)
      ROOT %r = s32[] add(%a, %b)
    }

    ENTRY %main (p0: f32[16]) -> f32[16] {
      %p0 = f32[16]{0} parameter(0)
      %cp0 = u16[1000]{0} collective-permute(%x0), source_target_pairs={{0,1},{1,0}}
      %cp1 = u16[500]{0} collective-permute(%x1), source_target_pairs={{0,1},{1,0}}
      %cp2 = f32[250]{0} collective-permute(%x2), source_target_pairs={{0,1},{1,0}}
      %ar0 = s32[100]{0} all-reduce(%x3), replica_groups={{0,1}}, to_apply=%add
      %ag0 = f32[64]{0} all-gather(%x4), replica_groups={{0,1}}, dimensions={0}
    }
""")


def test_comp_collectives_per_dtype_accounting():
    """by_dtype must split wire bytes by element type: the bf16-wire
    budget window reads exactly the u16 slice, so mixed programs (u16
    combine + f32 resharding + s32 control all-reduce) must not bleed
    across dtypes."""
    coll = HloCost(_MIXED_DTYPE_HLO, n_dev=2).collectives()
    per_op = coll["per_op"]
    cp = per_op["collective-permute"]
    assert cp["count"] == 3
    # permutes are point-to-point: wire bytes == result bytes, per dtype
    assert cp["by_dtype"]["u16"] == (1000 + 500) * 2
    assert cp["by_dtype"]["f32"] == 250 * 4
    assert cp["wire_bytes"] == sum(cp["by_dtype"].values())
    # ring all-reduce at K=2: result · 2(K−1)/K = result bytes
    ar = per_op["all-reduce"]
    assert ar["by_dtype"] == {"s32": 100 * 4}
    # all-gather at K=2: result · (K−1)/K = half the result bytes
    ag = per_op["all-gather"]
    assert ag["by_dtype"] == {"f32": 64 * 4 // 2}
    assert coll["total_bytes"] == (cp["wire_bytes"] + ar["wire_bytes"]
                                   + ag["wire_bytes"])
