"""The trip-count-aware HLO cost model (launch/hlo_cost.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis
from repro.launch.hlo_cost import HloCost, corrected_costs


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_multiply_by_trip_count():
    W = jnp.ones((256, 256))

    def scan_n(x):
        x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=7)
        return x

    txt = _compile_text(scan_n, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    c = HloCost(txt)
    assert c.flops() == pytest.approx(7 * 2 * 256 ** 3, rel=0.01)
    # XLA's own analysis undercounts (counts the body once) — that is the
    # reason this module exists
    raw = cost_analysis(jax.jit(scan_n).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile())
    assert raw["flops"] < c.flops() / 2


def test_plain_matmul_matches_xla():
    W = jnp.ones((128, 128))
    f = lambda x: x @ W
    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = _compile_text(f, spec)
    c = HloCost(txt)
    raw = cost_analysis(jax.jit(f).lower(spec).compile())
    assert c.flops() == pytest.approx(raw["flops"], rel=0.01)


def test_nested_scans_multiply():
    W = jnp.ones((64, 64))

    def inner(x):
        x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=3)
        return x

    def outer(x):
        x, _ = jax.lax.scan(lambda h, _: (inner(h), None), x, None, length=5)
        return x

    txt = _compile_text(outer, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    c = HloCost(txt)
    assert c.flops() == pytest.approx(15 * 2 * 64 ** 3, rel=0.05)


def test_bytes_scale_with_trip_count():
    W = jnp.ones((256, 256))

    def scan_n(n):
        def f(x):
            x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=n)
            return x
        return f

    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b2 = HloCost(_compile_text(scan_n(2), spec)).bytes_accessed()
    b8 = HloCost(_compile_text(scan_n(8), spec)).bytes_accessed()
    assert 2.5 < b8 / b2 < 5.0      # ~4× (plus fixed entry-block cost)


def test_corrected_costs_api():
    f = lambda x: jnp.sin(x) @ jnp.ones((32, 32))
    txt = _compile_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    out = corrected_costs(txt)
    assert out["flops"] > 0 and out["bytes"] > 0
