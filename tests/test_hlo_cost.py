"""The trip-count-aware HLO cost model (launch/hlo_cost.py)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.compat import cost_analysis
from repro.launch.hlo_cost import HloCost, corrected_costs


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_multiply_by_trip_count():
    W = jnp.ones((256, 256))

    def scan_n(x):
        x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=7)
        return x

    txt = _compile_text(scan_n, jax.ShapeDtypeStruct((256, 256), jnp.float32))
    c = HloCost(txt)
    assert c.flops() == pytest.approx(7 * 2 * 256 ** 3, rel=0.01)
    # XLA's own analysis undercounts (counts the body once) — that is the
    # reason this module exists
    raw = cost_analysis(jax.jit(scan_n).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile())
    assert raw["flops"] < c.flops() / 2


def test_plain_matmul_matches_xla():
    W = jnp.ones((128, 128))
    f = lambda x: x @ W
    spec = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = _compile_text(f, spec)
    c = HloCost(txt)
    raw = cost_analysis(jax.jit(f).lower(spec).compile())
    assert c.flops() == pytest.approx(raw["flops"], rel=0.01)


def test_nested_scans_multiply():
    W = jnp.ones((64, 64))

    def inner(x):
        x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=3)
        return x

    def outer(x):
        x, _ = jax.lax.scan(lambda h, _: (inner(h), None), x, None, length=5)
        return x

    txt = _compile_text(outer, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    c = HloCost(txt)
    assert c.flops() == pytest.approx(15 * 2 * 64 ** 3, rel=0.05)


def test_bytes_scale_with_trip_count():
    W = jnp.ones((256, 256))

    def scan_n(n):
        def f(x):
            x, _ = jax.lax.scan(lambda h, _: (h @ W, None), x, None, length=n)
            return x
        return f

    spec = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b2 = HloCost(_compile_text(scan_n(2), spec)).bytes_accessed()
    b8 = HloCost(_compile_text(scan_n(8), spec)).bytes_accessed()
    assert 2.5 < b8 / b2 < 5.0      # ~4× (plus fixed entry-block cost)


def test_corrected_costs_api():
    f = lambda x: jnp.sin(x) @ jnp.ones((32, 32))
    txt = _compile_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    out = corrected_costs(txt)
    assert out["flops"] > 0 and out["bytes"] > 0


# ---------------------------------------------------------------------------
# Dynamic-schedule combine: collective bytes scale with deg, not K
# (regression alongside the combine_every conditional-combine test in
# test_update.py — both pin communication cost at the HLO level)
# ---------------------------------------------------------------------------

_DYNAMIC_BYTES_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import compat
    from repro.core import diffusion, topology
    from repro.launch.hlo_cost import HloCost

    K, M = 8, 2048
    mesh = compat.make_mesh((K,), ("data",))
    phi = {"w": jax.random.normal(jax.random.key(0), (K, M), jnp.float32)}
    phi_sh = {"w": jax.device_put(phi["w"], NamedSharding(mesh, P("data", None)))}
    step = jnp.zeros((), jnp.int32)
    out = {"shard_bytes": M * 4}
    with mesh:
        for topo_name in ["ring", "full"]:
            topo = topology.build_topology(topo_name, K)
            sched = topology.make_schedule("link_failure", topo, p=0.3,
                                           period=8, seed=0)
            dyn = jax.jit(diffusion.make_combine(
                "mesh_sparse_dynamic", A=sched.matrices, mesh=mesh,
                axis_name="data", in_specs={"w": P("data", None)}))
            dense = jax.jit(diffusion.make_combine("dense", A=sched.matrices))
            rec = {"deg": sched.ir().degree}
            for name, fn in [("sparse", dyn), ("dense", dense)]:
                txt = fn.lower(phi_sh, step).compile().as_text()
                coll = HloCost(txt, n_dev=K).collectives()
                rec[name + "_bytes"] = coll["total_bytes"]
                rec[name + "_count"] = coll["total_count"]
                rec[name + "_permutes"] = coll["per_op"].get(
                    "collective-permute", {}).get("count", 0)
            out[topo_name] = rec
    print("HLO_BYTES_JSON:" + json.dumps(out))
""")


def test_sparse_dynamic_collective_bytes_scale_with_deg_not_K():
    """At K=8 the sparse_dynamic combine must move deg permutes of one
    shard each: deg=2 on the ring, deg=7 on the full graph — and the ring
    must stay under the (deg+1)/K bound of the dense-stacked bytes."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _DYNAMIC_BYTES_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."),
                         timeout=600)
    lines = [l for l in res.stdout.splitlines()
             if l.startswith("HLO_BYTES_JSON:")]
    assert lines, res.stderr[-2000:]
    out = json.loads(lines[0][len("HLO_BYTES_JSON:"):])
    shard = out["shard_bytes"]
    ring, full = out["ring"], out["full"]
    assert (ring["deg"], full["deg"]) == (2, 7)
    # deg collective-permutes of one local shard each — wire scales with
    # the offset-union degree, NOT with K
    assert ring["sparse_permutes"] == 2
    assert full["sparse_permutes"] == 7
    assert ring["sparse_bytes"] == 2 * shard
    assert full["sparse_bytes"] == 7 * shard
    # acceptance bound: ring sparse ≤ (deg+1)/K of the dense-stacked bytes
    assert ring["dense_bytes"] > 0
    assert ring["sparse_bytes"] <= (ring["deg"] + 1) / 8 * ring["dense_bytes"]
