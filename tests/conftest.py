import os
import sys

# Tests run on the real single CPU device — never the 512-device dry-run
# fake (see launch/dryrun.py, which sets XLA_FLAGS itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
