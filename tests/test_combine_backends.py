"""Cross-backend combine parity + backend registry behavior.

Acceptance: dense == sparse == pallas to <= 1e-5 on ring/torus/full, with
the pallas path serving parameter pytrees whose flattened size is NOT a
multiple of block_m (ragged-M), via the pack/unpack layer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diffusion as D
from repro.core import topology as T


def _ragged_phi(K, seed=0):
    """Leaf sizes 35 + 3 + 17 = 55 floats — nothing lane- or block-aligned."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    return {"w": jax.random.normal(k1, (K, 7, 5)),
            "b": jax.random.normal(k2, (K, 3)),
            "scale": jax.random.normal(k3, (K, 17))}


@pytest.mark.parametrize("topo", ["ring", "torus", "full"])
@pytest.mark.parametrize("K", [4, 8])
def test_dense_sparse_pallas_parity(topo, K):
    A = T.combination_matrix(K, topo)
    phi = _ragged_phi(K, seed=K)
    dense = D.make_combine("dense", A=A)(phi)
    sparse = D.make_combine("sparse_host", A=A)(phi)
    pallas = D.make_combine("pallas", A=A, interpret=True)(phi)
    for a, b, c in zip(jax.tree.leaves(dense), jax.tree.leaves(sparse),
                       jax.tree.leaves(pallas)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-5)


@pytest.mark.parametrize("block_m", [128, 512])
def test_pallas_handles_ragged_m(block_m):
    """Total flattened M = 55 is far from any block multiple; the packed
    path must pad, combine, and slice back exactly."""
    K = 6
    A = T.combination_matrix(K, "ring")
    phi = _ragged_phi(K, seed=1)
    total = sum(int(np.prod(x.shape[1:])) for x in jax.tree.leaves(phi))
    assert total % block_m != 0
    out = D.make_combine("pallas", A=A, block_m=block_m, interpret=True)(phi)
    ref = D.make_combine("dense", A=A)(phi)
    assert jax.tree.structure(out) == jax.tree.structure(phi)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_pallas_mixed_dtype_pytree():
    K = 4
    A = T.combination_matrix(K, "ring")
    k1, k2 = jax.random.split(jax.random.key(0))
    phi = {"f32": jax.random.normal(k1, (K, 9)),
           "bf16": jax.random.normal(k2, (K, 5)).astype(jnp.bfloat16)}
    out = D.make_combine("pallas", A=A, interpret=True)(phi)
    assert out["f32"].dtype == jnp.float32
    assert out["bf16"].dtype == jnp.bfloat16
    ref = D.dense_combine(jnp.asarray(A), phi)
    np.testing.assert_allclose(np.asarray(out["f32"]),
                               np.asarray(ref["f32"]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["bf16"], np.float32),
                               np.asarray(ref["bf16"], np.float32), atol=2e-2)


def test_pack_pytree_roundtrip_and_alignment():
    K = 5
    phi = _ragged_phi(K)
    bufs, unpack = D.pack_pytree(phi, block_m=512)
    assert len(bufs) == 1                      # single dtype group
    assert bufs[0].shape == (K, 512)           # padded to one block
    assert bufs[0].shape[1] % D.LANE == 0      # lane-aligned
    back = unpack(bufs)
    for a, b in zip(jax.tree.leaves(phi), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_combine_inside_jit():
    K = 4
    A = T.combination_matrix(K, "full")
    phi = _ragged_phi(K, seed=3)
    fn = jax.jit(D.make_combine("pallas", A=A, interpret=True))
    ref = D.dense_combine(jnp.asarray(A), phi)
    for a, b in zip(jax.tree.leaves(fn(phi)), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# Registry / selection
# ---------------------------------------------------------------------------

def test_registry_contains_all_backends():
    names = D.combine_backends()
    for expected in ("dense", "sparse_host", "sparse", "mesh_sparse",
                     "sparse_host_dynamic", "sparse_dynamic",
                     "mesh_sparse_dynamic", "pallas", "centralized", "none"):
        assert expected in names


def test_make_combine_rejects_unknown():
    with pytest.raises(ValueError, match="registered"):
        D.make_combine("bogus", A=np.eye(2))


def test_select_backend_rules():
    assert D.select_backend(np.ones((1, 1))) == "none"
    ring = T.combination_matrix(8, "ring")
    assert D.select_backend(ring) == "sparse_host"
    full = T.combination_matrix(8, "full")          # degree K-1: dense wins
    assert D.select_backend(full) in ("dense", "pallas")
    # a live mesh whose agent axis matches K upgrades ring to mesh_sparse
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((8, 2), ("data", "model"))
    assert D.select_backend(ring, mesh=mesh, axis_name="data") == "mesh_sparse"
    # mismatched extent falls back to the host roll
    assert D.select_backend(ring, mesh=mesh, axis_name="model") == "sparse_host"


def test_auto_strategy_through_make_combine():
    K = 6
    A = T.combination_matrix(K, "ring")
    phi = _ragged_phi(K)
    out = D.make_combine("auto", A=A)(phi)
    ref = D.dense_combine(jnp.asarray(A), phi)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_combine_wire_bytes_model():
    K = 8
    ring = T.combination_matrix(K, "ring")
    mb = 1000
    assert D.combine_wire_bytes(ring, "none", mb) == 0
    assert D.combine_wire_bytes(ring, "sparse_host", mb) == 2 * mb  # deg 2
    assert D.combine_wire_bytes(ring, "dense", mb) == (K - 1) * mb
    assert D.combine_wire_bytes(ring, "centralized", mb) == 2 * (K - 1) * mb // K


# ---------------------------------------------------------------------------
# Trainer integration: pallas backend trains identically to dense
# ---------------------------------------------------------------------------

def test_trainer_pallas_matches_dense_and_disagreement_decays():
    from repro.configs import get_config
    from repro.core import MetaConfig, init_state, make_meta_step, diffusion
    from repro.data.sine import agent_sine_distributions, stacked_agent_batch
    from repro.models.simple import SineMLP

    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    common = dict(num_agents=6, tasks_per_agent=2, inner_lr=0.01,
                  mode="maml", topology="ring", outer_optimizer="sgd",
                  outer_lr=5e-3)

    def run(combine, steps=8, interpret=True):
        mcfg = MetaConfig(combine=combine, **common)
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=False)
        A = T.combination_matrix(6, "ring")
        combine_fn = (D.make_combine("pallas", A=A, interpret=True)
                      if combine == "pallas" else None)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg,
                                      combine_fn=combine_fn))
        dists = agent_sine_distributions(6)
        ds = [float(diffusion.disagreement(state.params))]
        for _ in range(steps):
            sup, qry = stacked_agent_batch(dists, 2, 10)
            state, metrics = step(state, jax.tree.map(jnp.asarray, sup),
                                  jax.tree.map(jnp.asarray, qry))
            ds.append(float(metrics["disagreement"]))
        return state, ds

    sa, _ = run("dense")
    sb, ds = run("pallas")
    for a, b in zip(jax.tree.leaves(sa.params), jax.tree.leaves(sb.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # disagreement-decay smoke (Thm 1): combine contracts the network
    assert ds[-1] < ds[0]
