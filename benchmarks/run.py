"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of one
jitted training/eval step on this host; derived = the figure's headline
quantity).  Detailed curves are written to results/benchmarks/*.json.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (MetaConfig, TopologyConfig, UpdateConfig, init_state,
                        make_eval_fn, make_meta_step, diffusion, topology)
from repro.data import (Episode, FewShotTaskSource, MetaBatchPipeline,
                        SineTaskSource)
from repro.models.simple import FewShotCNN, SineMLP

_DEVICE_EP = Episode.to_device

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "benchmarks")
ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us: float, derived: str, detail: dict | None = None):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")
    if detail is not None:
        os.makedirs(RESULTS, exist_ok=True)
        with open(os.path.join(RESULTS, f"{name}.json"), "w") as f:
            json.dump(detail, f, indent=1)


def _timed(fn, *args, reps=5):
    """Median-of-reps wall time (us) with a per-rep ``block_until_ready``.

    The old mean-with-one-trailing-block protocol had two failure modes on
    2-vCPU CI: async dispatch let reps overlap (the loop timed enqueue, not
    execution, for all but the last rep) and a single noisy rep skewed the
    mean.  Blocking each rep and taking the median fixes both."""
    jax.block_until_ready(fn(*args))            # compile + warm caches
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


# ---------------------------------------------------------------------------
# Shared sine harness (paper §4.1 setup: K=6, Fig 2a graph, Adam mu=1e-3)
# ---------------------------------------------------------------------------

def _sine_train(strategy: str, steps: int, seed: int = 0, mode: str = "maml",
                outer: str = "adam", lr: float = 1e-3, eval_every: int = 50,
                source: SineTaskSource | None = None, param_dtype=None):
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    K = 6
    combine = {"dif": "dense", "centralized": "centralized",
               "noncoop": "none"}[strategy]
    mcfg = MetaConfig(num_agents=K, tasks_per_agent=5, inner_lr=cfg.inner_lr,
                      mode=mode, combine=combine, topology="paper",
                      outer_optimizer=outer, outer_lr=lr)
    init_fn = (model.init if param_dtype is None
               else lambda k: model.init(k, param_dtype))
    state = init_state(jax.random.key(seed), init_fn, mcfg,
                       identical_init=True)
    step = jax.jit(make_meta_step(model.loss_fn, mcfg))
    if source is None:
        source = SineTaskSource(K=K, tasks_per_agent=5, shots=10, seed=seed)
    evaln = make_eval_fn(model.loss_fn, inner_lr=cfg.inner_lr, inner_steps=1)
    ev = source.eval_sample(200, seed=999)      # full-range eval (paper)
    esup = jax.tree.map(jnp.asarray, ev.support)
    eqry = jax.tree.map(jnp.asarray, ev.query)
    curve, step_us = [], None
    with MetaBatchPipeline(source, depth=2, prepare=_DEVICE_EP) as pipe:
        for i in range(steps):
            support, query = next(pipe)
            t0 = time.perf_counter()
            state, metrics = step(state, support, query)
            if i == steps - 1:
                jax.block_until_ready(metrics["loss"])
                step_us = (time.perf_counter() - t0) * 1e6
            if i % eval_every == 0 or i == steps - 1:
                if strategy == "noncoop":
                    # paper protocol: average of per-agent test losses
                    losses = []
                    for k in range(K):
                        pk = jax.tree.map(lambda x: x[k], state.params)
                        losses.append(float(np.mean(np.asarray(
                            evaln(pk, esup, eqry))[:, 1])))
                    curve.append((i, float(np.mean(losses))))
                else:
                    # eval the centroid in f32 so bf16-storage runs measure
                    # training drift, not eval-precision noise (no-op at f32)
                    c = jax.tree.map(lambda x: x.astype(jnp.float32),
                                     diffusion.centroid(state.params))
                    l = float(np.mean(np.asarray(evaln(c, esup, eqry))[:, 1]))
                    curve.append((i, l))
    return state, model, curve, step_us


def bench_fig2b_sine_regression(quick: bool):
    """Fig 2b: test loss during training — centralized vs Dif vs non-coop."""
    steps = 200 if quick else 1000
    out = {}
    for strat in ["centralized", "dif", "noncoop"]:
        _, _, curve, us = _sine_train(strat, steps)
        out[strat] = curve
        emit(f"fig2b_sine_{strat}", us,
             f"final_test_loss={curve[-1][1]:.4f}")
    gap_cd = out["dif"][-1][1] - out["centralized"][-1][1]
    gap_nd = out["noncoop"][-1][1] - out["dif"][-1][1]
    emit("fig2b_summary", 0.0,
         f"dif_minus_centralized={gap_cd:.4f};noncoop_minus_dif={gap_nd:.4f}",
         detail=out)


def bench_fig2c_adaptation_steps(quick: bool):
    """Fig 2c: post-training test loss vs number of adaptation steps."""
    steps = 200 if quick else 1000
    n_adapt = 10
    ep = SineTaskSource(K=6).eval_sample(200, seed=777)
    esup = jax.tree.map(jnp.asarray, ep.support)
    eqry = jax.tree.map(jnp.asarray, ep.query)
    out = {}
    for strat in ["centralized", "dif", "noncoop"]:
        state, model, _, us = _sine_train(strat, steps)
        ev = make_eval_fn(model.loss_fn, inner_lr=0.01, inner_steps=n_adapt)
        if strat == "noncoop":
            curves = []
            for k in range(6):
                pk = jax.tree.map(lambda x: x[k], state.params)
                curves.append(np.asarray(ev(pk, esup, eqry)).mean(0))
            curve = np.mean(curves, axis=0)
        else:
            c = diffusion.centroid(state.params)
            curve = np.asarray(ev(c, esup, eqry)).mean(0)
        out[strat] = curve.tolist()
        emit(f"fig2c_adapt_{strat}", us,
             f"loss_step1={curve[1]:.4f};loss_step10={curve[10]:.4f}")
    emit("fig2c_summary", 0.0,
         "ordering_preserved=%s" % (out["dif"][10] < out["noncoop"][10]),
         detail=out)


def bench_fig3_fewshot_classification(quick: bool):
    """Fig 3 analogue: few-shot classification (synthetic Omniglot
    surrogate), centralized vs Dif vs non-coop, 5-way 1-shot."""
    steps = 60 if quick else 300
    cfg = get_config("omniglot_cnn")
    source = FewShotTaskSource(K=6, tasks_per_agent=2, n_classes=80,
                               n_way=cfg.vocab_size, k_shot=1, n_query=5,
                               seed=0)
    model = FewShotCNN(cfg, image_hw=source.image_hw)
    test_ep = source.eval_sample(50, seed=4242)       # meta-test classes
    tsup = jax.tree.map(jnp.asarray, test_ep.support)
    tqry = jax.tree.map(jnp.asarray, test_ep.query)
    out = {}
    for strat in ["centralized", "dif", "noncoop"]:
        combine = {"dif": "dense", "centralized": "centralized",
                   "noncoop": "none"}[strat]
        mcfg = MetaConfig(num_agents=6, tasks_per_agent=2, inner_lr=cfg.inner_lr,
                          mode="maml", combine=combine, topology="paper",
                          outer_optimizer="adam", outer_lr=1e-3)
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=True)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        us = None
        accs = []
        with MetaBatchPipeline(source, depth=2, prepare=_DEVICE_EP) as pipe:
            for i in range(steps):
                sup, qry = next(pipe)
                t0 = time.perf_counter()
                state, m = step(state, sup, qry)
                if i == steps - 1:
                    jax.block_until_ready(m["loss"])
                    us = (time.perf_counter() - t0) * 1e6
                if i % max(1, steps // 5) == 0 or i == steps - 1:
                    c = diffusion.centroid(state.params)
                    accs_k = []
                    agents = range(6) if strat == "noncoop" else [None]
                    for k in agents:
                        p = c if k is None else jax.tree.map(lambda x: x[k],
                                                             state.params)
                        def adapted_acc(sx_, sy_, qx_, qy_):
                            g = jax.grad(model.loss_fn)(p, (sx_, sy_))
                            pa = jax.tree.map(
                                lambda a, b: a - cfg.inner_lr * b, p, g)
                            return model.accuracy(pa, (qx_, qy_))
                        acc = jnp.mean(jax.vmap(adapted_acc)(
                            tsup[0], tsup[1], tqry[0], tqry[1]))
                        accs_k.append(float(acc))
                    accs.append((i, float(np.mean(accs_k))))
        out[strat] = accs
        emit(f"fig3_fewshot_{strat}", us, f"final_test_acc={accs[-1][1]:.4f}")
    emit("fig3_summary", 0.0,
         "dif_ge_noncoop=%s" % (out["dif"][-1][1] >= out["noncoop"][-1][1] - 0.02),
         detail=out)


def bench_thm1_agreement(quick: bool):
    """Thm 1: network disagreement decays linearly at rate lambda_2, then
    plateaus at O(mu^2)."""
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    rows = {}
    for mu in [5e-3, 1e-3]:
        mcfg = MetaConfig(num_agents=6, tasks_per_agent=3, inner_lr=0.01,
                          mode="maml", combine="dense", topology="ring",
                          outer_optimizer="sgd", outer_lr=mu)
        state = init_state(jax.random.key(1), model.init, mcfg,
                           identical_init=False)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        source = SineTaskSource(K=6, tasks_per_agent=3, shots=10)
        ds = [float(diffusion.disagreement(state.params))]
        with MetaBatchPipeline(source, depth=2, prepare=_DEVICE_EP) as pipe:
            for i in range(80 if quick else 300):
                sup, qry = next(pipe)
                state, m = step(state, sup, qry)
                ds.append(float(m["disagreement"]))
        rows[f"mu={mu}"] = ds
        plateau = float(np.mean(ds[-20:]))
        emit(f"thm1_agreement_mu{mu}", 0.0,
             f"plateau={plateau:.3e};decay10={ds[10]/ds[0]:.3e}")
    lam2 = topology.mixing_rate(topology.combination_matrix(6, "ring"))
    p1 = np.mean(rows["mu=0.005"][-20:])
    p2 = np.mean(rows["mu=0.001"][-20:])
    emit("thm1_summary", 0.0,
         f"lambda2={lam2:.3f};plateau_ratio={(p1 / p2):.1f};mu_ratio_sq=25.0",
         detail=rows)


def bench_thm2_stationarity(quick: bool):
    """Thm 2/Cor 1: ||grad J(centroid)||^2 reaches an O(mu) ball."""
    from repro.core import maml
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    source = SineTaskSource(K=6, tasks_per_agent=5, shots=10)
    out = {}
    for mu in [2e-3, 5e-4]:
        mcfg = MetaConfig(num_agents=6, tasks_per_agent=5, inner_lr=0.01,
                          mode="maml", combine="dense", topology="paper",
                          outer_optimizer="sgd", outer_lr=mu)
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=True)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))

        @jax.jit
        def grad_norm_sq(params_c, sup, qry):
            def one_agent(s, q):
                _, g = maml.multi_task_meta_grad(model.loss_fn, params_c,
                                                 s, q, alpha=0.01)
                return g
            gs = jax.vmap(one_agent)(sup, qry)
            g_mean = jax.tree.map(lambda x: jnp.mean(x, 0), gs)
            return sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g_mean))

        norms = []
        with MetaBatchPipeline(source, depth=2, prepare=_DEVICE_EP) as pipe:
            for i in range(100 if quick else 400):
                sup, qry = next(pipe)
                state, _ = step(state, sup, qry)
                if i % 20 == 0:
                    c = diffusion.centroid(state.params)
                    norms.append(float(grad_norm_sq(c, sup, qry)))
        out[f"mu={mu}"] = norms
        emit(f"thm2_stationarity_mu{mu}", 0.0,
             f"grad_norm_sq_final={norms[-1]:.3e};initial={norms[0]:.3e}")
    emit("thm2_summary", 0.0, "smaller_mu_smaller_ball=%s"
         % (np.min(out["mu=0.0005"]) <= np.min(out["mu=0.002"]) * 2),
         detail=out)


def bench_combine_strategies(quick: bool):
    """Combine backend shoot-out through the unified registry entry point:
    wall time + modeled collective bytes/step per backend, on a 1M-param
    launch model, K=16 ring.  The pallas backend runs compiled on TPU and
    in interpreter mode elsewhere (correctness row, not a perf row)."""
    K = 16
    A = topology.combination_matrix(K, "ring")
    lam2 = topology.mixing_rate(A)
    phi = {"w": jax.random.normal(jax.random.key(0), (K, 1024, 1024)),
           "b": jax.random.normal(jax.random.key(1), (K, 4096))}
    nbytes = sum(x.nbytes // K for x in jax.tree.leaves(phi))
    on_tpu = jax.default_backend() == "tpu"
    backends = ["dense", "sparse_host", "centralized", "pallas"]
    outs = {}
    for name in backends:
        # interpreter-mode pallas: bigger blocks keep the grid (and the
        # python-loop interpret overhead) small
        bm = 8192 if (name == "pallas" and not on_tpu) else 512
        fn = jax.jit(diffusion.make_combine(name, A=A, block_m=bm))
        us = _timed(fn, phi)
        outs[name] = fn(phi)
        wire = diffusion.combine_wire_bytes(A, name, nbytes)
        # centralized replaces A with (1/K)11^T, whose mixing rate is 0
        lam = 0.0 if name == "centralized" else lam2
        emit(f"combine_{name}", us,
             f"combine_bytes_step={wire};lambda2={lam:.3f}"
             + ("" if name != "pallas" or on_tpu else ";interpret=1"))
    auto = diffusion.select_backend(A)
    emit("combine_auto_selects", 0.0, f"backend={auto}")
    ref = jax.tree.leaves(outs["dense"])
    for name in ["sparse_host", "pallas"]:
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(ref, jax.tree.leaves(outs[name])))
        emit(f"combine_{name}_equals_dense", 0.0, f"max_err={err:.2e}")


# Runs under 8 forced host devices in a subprocess (the parent process owns
# a single-device jax runtime): lowers the dense-stacked and the
# mesh_sparse_dynamic combine for each dynamic schedule × topology, reads
# collective wire bytes off the optimized HLO (launch/hlo_cost.py), and
# times both with the median-of-reps protocol.
_DYNAMIC_COMBINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.core import diffusion, topology
from repro.launch.hlo_cost import HloCost
from benchmarks.run import _timed as timed   # ONE timing protocol

K = 8
M = int(sys.argv[1])
mesh = compat.make_mesh((K,), ("data",))
phi = {"w": jax.random.normal(jax.random.key(0), (K, M), jnp.float32)}
sh = NamedSharding(mesh, P("data", None))
phi_sh = {"w": jax.device_put(phi["w"], sh)}
step0 = jnp.zeros((), jnp.int32)

out = {}
with mesh:
    for topo_name in ["ring", "full"]:
        topo = topology.build_topology(topo_name, K)
        for kind, kw in [("link_failure", dict(p=0.3, period=16, seed=0)),
                         ("gossip", dict(period=16, seed=0)),
                         ("round_robin", {})]:
            sched = topology.make_schedule(kind, topo, **kw)
            stack = sched.matrices           # always (S, K, K)
            dense = jax.jit(diffusion.make_combine("dense", A=stack))
            dyn = jax.jit(diffusion.make_combine(
                "mesh_sparse_dynamic", A=stack, mesh=mesh,
                axis_name="data", in_specs={"w": P("data", None)}))
            rec = {"period": sched.period, "deg": sched.ir().degree}
            for name, fn in [("dense", dense), ("sparse_dynamic", dyn)]:
                txt = fn.lower(phi_sh, step0).compile().as_text()
                coll = HloCost(txt, n_dev=K).collectives()
                rec[name] = {"wire_bytes": coll["total_bytes"],
                             "collectives": coll["total_count"],
                             "us": timed(fn, phi_sh, step0)}
            s = jnp.int32(3)
            err = jnp.max(jnp.abs(dense(phi_sh, s)["w"] - dyn(phi_sh, s)["w"]))
            rec["max_err"] = float(err)
            out[kind + "_" + topo_name] = rec

    # bf16 wire vs the f32 escape hatch on the K=8 ring: same bf16 phi,
    # same backend, only the wire format differs.  Permute bytes come off
    # the optimized HLO — the bf16 payload rides as u16 (2 B/elem; see the
    # wire-format contract in core/diffusion.py), so the halving is real
    # on-wire, not a trace-level fiction the CPU backend re-widens.
    ring = topology.build_topology("ring", K)
    rr = topology.make_schedule("round_robin", ring)
    phi_bf = jax.tree.map(lambda x: x.astype(jnp.bfloat16), phi_sh)
    for bname, Amat, extra in [
            ("mesh_sparse", ring.matrix, ()),
            ("mesh_sparse_dynamic", rr.stacked(), (step0,))]:
        rec, lint = {}, {}
        for wire in ["float32", "bfloat16"]:
            fn = jax.jit(diffusion.make_combine(
                bname, A=Amat, mesh=mesh, axis_name="data",
                in_specs={"w": P("data", None)}, combine_dtype=wire))
            txt = fn.lower(phi_bf, *extra).compile().as_text()
            cp = HloCost(txt, n_dev=K).collectives()["per_op"].get(
                "collective-permute", {"wire_bytes": 0, "by_dtype": {}})
            rec[wire] = {"permute_bytes": cp["wire_bytes"],
                         "by_dtype": cp["by_dtype"],
                         "us": timed(fn, phi_bf, *extra),
                         "out": fn(phi_bf, *extra)}
            if wire == "bfloat16":
                # the u16-wire invariant now lives in the lint registry:
                # deg=2 on the K=8 ring, shard = M bf16 elems = 2M wire B
                from repro.analysis.rules import LintContext, run_rules
                ctx = LintContext(hlo=txt, n_dev=K, K=K, degree=2,
                                  shard_bytes=M * 2, wire_dtype="bfloat16")
                rep = run_rules(ctx, only=["collective-budget",
                                           "wire-dtype-leak"])
                lint = {"ok": rep.to_json()["ok"],
                        "checked": rep.checked,
                        "findings": [f.message for f in rep.findings]}
        err = float(jnp.max(jnp.abs(
            rec["bfloat16"]["out"]["w"].astype(jnp.float32)
            - rec["float32"]["out"]["w"].astype(jnp.float32))))
        out["wire_" + bname] = {
            "wire_bytes_bf16": rec["bfloat16"]["permute_bytes"],
            "wire_bytes_f32": rec["float32"]["permute_bytes"],
            "by_dtype_bf16": rec["bfloat16"]["by_dtype"],
            "us_bf16": rec["bfloat16"]["us"],
            "us_f32": rec["float32"]["us"],
            "max_err_vs_f32_wire": err,
            "lint": lint}
print("BENCH_JSON:" + json.dumps(out))
"""


def bench_combine_dynamic(quick: bool):
    """Dynamic-schedule combine: collective wire bytes (HLO-verified) and
    wall time, dense-stacked step-indexed einsum vs the sparse_dynamic
    ppermute lowering, per schedule × {ring, full} at K=8 on an 8-shard
    agent mesh.  On ring (deg 2) the sparse path must move ≤ (deg+1)/K of
    the dense bytes per combine — the acceptance row CI records."""
    import subprocess
    M = 1 << 13 if quick else 1 << 15
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _DYNAMIC_COMBINE_SCRIPT, str(M)],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=1200)
    lines = [l for l in res.stdout.splitlines()
             if l.startswith("BENCH_JSON:")]
    if not lines:
        raise RuntimeError(
            f"combine_dynamic subprocess failed:\n{res.stderr[-2000:]}")
    data = json.loads(lines[0][len("BENCH_JSON:"):])
    for name, rec in data.items():
        if name.startswith("wire_"):
            # bf16 wire vs f32 escape hatch, same backend and bf16 phi:
            # the acceptance row — ratio ≤ 0.55 (HLO-verified; exactly 0.5
            # up to rounding since the payload rides as 2-byte u16)
            ratio = rec["wire_bytes_bf16"] / max(rec["wire_bytes_f32"], 1)
            emit(f"combine_{name}_bf16", rec["us_bf16"],
                 f"f32_us={rec['us_f32']:.1f};"
                 f"wire_bf16={rec['wire_bytes_bf16']};"
                 f"wire_f32={rec['wire_bytes_f32']};"
                 f"bytes_ratio={ratio:.3f};"
                 f"within_055={ratio <= 0.55};K=8;"
                 f"lint_clean={rec['lint'].get('ok', False)};"
                 f"max_err_vs_f32_wire={rec['max_err_vs_f32_wire']:.2e}")
            continue
        dense, sp = rec["dense"], rec["sparse_dynamic"]
        ratio = sp["wire_bytes"] / max(dense["wire_bytes"], 1)
        emit(f"combine_dynamic_{name}", sp["us"],
             f"dense_us={dense['us']:.1f};"
             f"wire_sparse={sp['wire_bytes']};wire_dense={dense['wire_bytes']};"
             f"bytes_ratio={ratio:.3f};deg={rec['deg']};K=8;"
             f"max_err={rec['max_err']:.2e}")
    ring = data["link_failure_ring"]
    ring_ratio = (ring["sparse_dynamic"]["wire_bytes"]
                  / max(ring["dense"]["wire_bytes"], 1))
    emit("combine_dynamic_summary", 0.0,
         f"ring_bytes_ratio={ring_ratio:.3f};"
         f"bound_deg_plus_1_over_K={(ring['deg'] + 1) / 8:.3f};"
         f"ring_within_bound={ring_ratio <= (ring['deg'] + 1) / 8}",
         detail=data)


def bench_superstep(quick: bool):
    """Dispatch-free training loop: steps/sec of the superstep driver at
    C=1 (one jitted dispatch + one host metric fetch per step — the legacy
    loop's behavior) vs C=8 (one per 8 steps).  On dispatch-bound hardware
    the win is the Python/sync overhead times (C−1)/C."""
    from repro.configs.base import ArchConfig, InputShape
    from repro.data import LMTaskSource
    from repro.launch.mesh import make_host_mesh
    from repro.launch import steps as S

    seq, gb = 32, 8
    cfg = ArchConfig(name="superstep-bench", arch_type="dense", num_layers=1,
                     d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     d_ff=64, vocab_size=256, meta_mode="fomaml",
                     topology="ring", outer_optimizer="adam",
                     dtype="float32", remat=False, attn_q_chunk=None,
                     meta_tasks=2)
    shape = InputShape("superstep_bench", seq, gb, "train")
    mesh = make_host_mesh(data=min(4, len(jax.devices())))
    with mesh:
        bundle = S.build_train(cfg, mesh, shape)
        source = LMTaskSource(vocab_size=cfg.padded_vocab, seq_len=seq,
                              K=bundle.K, tasks_per_agent=bundle.T,
                              task_batch=bundle.tb, n_domains=8, seed=0)
        superstep = S.make_superstep(bundle.step_fn)
        fns = {C: jax.jit(superstep, donate_argnums=(0,))
               for C in (1, 8)}
        n_steps = 32 if quick else 64

        def run(C):
            fn = fns[C]
            st = bundle.init_state(seed=0)
            with bundle.make_pipeline(source, depth=2, stack=C) as pipe:
                for _ in range(2):               # compile + warm caches
                    st, m = fn(st, next(pipe))
                jax.device_get(m)
                t0 = time.perf_counter()
                for _ in range(n_steps // C):
                    st, m = fn(st, next(pipe))
                    jax.device_get(m)            # per-dispatch host sync
                return (n_steps // C) / (time.perf_counter() - t0) * C

        run(1)                                   # process burn-in
        r = {1: [], 8: []}
        for _ in range(3 if quick else 5):       # alternate reps (2-vCPU
            for C in (1, 8):                     # clock drift protocol)
                r[C].append(run(C))
        sps = {C: float(np.median(v)) for C, v in r.items()}
        emit("superstep", 1e6 / sps[8],
             f"steps_per_s_c8={sps[8]:.1f};steps_per_s_c1={sps[1]:.1f};"
             f"speedup={sps[8] / sps[1]:.2f}x",
             detail={"steps_per_s": {str(C): v for C, v in r.items()}})


def bench_serve(quick: bool):
    """Serving tier (adaptation-as-a-service): (1) N=8 concurrent user
    episodes adapted in ONE vmapped dispatch vs 8 sequential serve.py-style
    adapts (fresh per-request jit — the legacy path); (2) adapted-state
    cache: recurring-task hit (low-rank delta reconstruction) vs
    re-adaptation, plus the delta fidelity (|Δ adapted query loss|) and
    compression ratio; (3) scanned two-phase decode vs the legacy
    per-token python loop; (4) adapt p50/p99 + adapted-decodes/sec vs
    concurrent users × recurring fraction.  The two CI-pinned thresholds
    (batched ≥3× cold-sequential, cache hit ≥5× faster than re-adapt)
    raise on violation."""
    from repro.configs.base import ArchConfig
    from repro.core import maml
    from repro.launch.serve import make_support_source
    from repro.models.transformer import build_model
    from repro.serve import AdaptRequest, ServeEngine
    from repro.serve.cache import AdaptedStateCache

    cfg = ArchConfig(name="serve-bench", arch_type="dense", num_layers=1,
                     d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     d_ff=64, vocab_size=256, dtype="float32", remat=False,
                     attn_q_chunk=None, inner_lr=1e-2, inner_steps=1)
    P, G, B, N = 8, 16, 4, 8
    steps = 2
    reps = 3 if quick else 8
    engine = ServeEngine(cfg, prompt_len=P, gen=G, batch=B,
                         adapt_steps=steps, buckets=(1, 2, 4, 8))
    model = build_model(cfg)
    params = model.init(jax.random.key(0), jnp.float32)
    engine.load_params(params)
    source = make_support_source(cfg, P + G, B)
    ep = source.eval_sample(N, seed=3, split="full")
    sup = [{k: v[i] for k, v in ep.support.items()} for i in range(N)]
    qry = [{k: v[i] for k, v in ep.query.items()} for i in range(N)]
    # forced-distinct keys: eval_sample may repeat domains, and a shared
    # key would make two users alias one cache entry
    keyed = [AdaptRequest(sup[i], engine.signature(source, 1000 + i))
             for i in range(N)]
    keyless = [AdaptRequest(s) for s in sup]

    # --- (1) batched vmapped adapt vs sequential ------------------------
    engine.adapt(keyless)                        # compile bucket-8
    batched_s = []
    for _ in range(reps):
        t0 = time.perf_counter()
        engine.adapt(keyless)
        batched_s.append(time.perf_counter() - t0)
    batched = float(np.median(batched_s))

    def adapt_one_fn():
        return jax.jit(lambda p, b: maml.inner_adapt(
            model.loss_fn, p, b, alpha=cfg.inner_lr, steps=steps,
            first_order=True))

    warm_fn = adapt_one_fn()
    dev_sup = [{k: jnp.asarray(v) for k, v in s.items()} for s in sup]
    jax.block_until_ready(warm_fn(params, dev_sup[0]))
    t0 = time.perf_counter()
    for s in dev_sup:
        jax.block_until_ready(warm_fn(params, s))
    warm_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    for s in dev_sup:                            # serve.py-style: a fresh
        f = adapt_one_fn()                       # jit per request, so every
        jax.block_until_ready(f(params, s))      # request retraces
    cold_seq = time.perf_counter() - t0
    x_cold, x_warm = cold_seq / batched, warm_seq / batched
    emit("serve_adapt_batched", batched * 1e6 / N,
         f"n={N};batched_s={batched:.4f};cold_seq_s={cold_seq:.2f};"
         f"warm_seq_s={warm_seq:.4f};throughput_x_cold={x_cold:.1f};"
         f"throughput_x_warm={x_warm:.2f};meets_3x={x_cold >= 3.0}",
         detail={"batched_s": batched_s, "cold_seq_s": cold_seq,
                 "warm_seq_s": warm_seq})

    # --- (2) cache hit vs re-adaptation + delta fidelity ----------------
    full_adapted, _ = engine.adapt(keyed)        # misses: fill the cache
    engine.adapt(keyed)                          # compile the hit path
    miss_s, hit_s = [], []
    for _ in range(reps):
        engine.cache._store.clear()              # force misses (warm fns)
        t0 = time.perf_counter()
        engine.adapt(keyed)
        miss_s.append((time.perf_counter() - t0) / N)
        t0 = time.perf_counter()
        rec_adapted, _ = engine.adapt(keyed)
        hit_s.append((time.perf_counter() - t0) / N)
    miss_us, hit_us = np.median(miss_s) * 1e6, np.median(hit_s) * 1e6
    speedup = miss_us / hit_us
    l_full = engine.adapted_loss(full_adapted, qry)
    l_rec = engine.adapted_loss(rec_adapted, qry)
    drift = float(np.max(np.abs(l_full - l_rec)))
    stats = engine.cache.stats()
    emit("serve_cache_hit", hit_us,
         f"readapt_us={miss_us:.0f};speedup={speedup:.1f}x;"
         f"meets_5x={speedup >= 5.0};loss_drift={drift:.5f};"
         f"drift_ok={drift <= 1e-2};compression={stats['compression']:.2f}x",
         detail={"miss_s": miss_s, "hit_s": hit_s, "cache": stats,
                 "loss_full": l_full.tolist(), "loss_rec": l_rec.tolist()})

    # --- (3) scanned decode vs per-token python loop --------------------
    prompt = np.asarray(ep.query["tokens"][0])[:, :P]
    a0 = full_adapted[0]
    engine.decode(a0, prompt)                    # compile both scans
    dm = None
    for _ in range(reps):
        _, dm = engine.decode(a0, prompt)
    step = jax.jit(engine.bundle.step_fn)        # legacy loop baseline

    def py_loop():
        cache = model.init_cache(B, P + G, jnp.float32, params=a0)
        tok = jnp.asarray(prompt[:, :1])
        for t in range(P + G - 1):
            logits, cache = step(a0, cache, tok, jnp.full((B,), t, jnp.int32))
            if t + 1 < P:
                tok = jnp.asarray(prompt[:, t + 1: t + 2])
            else:
                tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(
                    jnp.int32)
                np.asarray(tok)                  # the per-token host sync
        return tok

    loop_us = _timed(py_loop, reps=reps)
    scan_us = (dm["prefill_s"] + dm["decode_s"]) * 1e6
    emit("serve_decode", scan_us,
         f"prompt_tok_s={dm['prompt_tok_s']:.0f};"
         f"decode_tok_s={dm['decode_tok_s']:.0f};"
         f"pyloop_us={loop_us:.0f};speedup_vs_pyloop={loop_us / scan_us:.1f}x",
         detail={"scan": dm, "pyloop_us": loop_us})

    # --- (4) adapt latency + adapted-decodes/sec vs users × recurring ---
    sweep: dict[str, dict] = {}
    for users in (1, 2, 4, 8):
        u_sup = sup[:users]
        u_keyed = keyed[:users]
        engine.adapt([AdaptRequest(s) for s in u_sup])   # compile bucket
        row = {}
        for frac_name, frac in [("cold", 0.0), ("mixed", 0.5),
                                ("recurring", 1.0)]:
            n_rec = int(users * frac)
            # recurring users resolve from the cache; the rest opt out of
            # caching so every rep re-measures a genuine miss
            requests = u_keyed[:n_rec] + [AdaptRequest(s)
                                          for s in u_sup[n_rec:]]
            engine.cache = AdaptedStateCache(capacity=64)
            if n_rec:
                engine.adapt(u_keyed[:n_rec])    # residents + compile
            lat, thru = [], []
            for _ in range(reps):
                t0 = time.perf_counter()
                adapted, _ = engine.adapt(requests)
                adapt_s = time.perf_counter() - t0
                t0 = time.perf_counter()
                for a in adapted:
                    engine.decode(a, prompt)
                dec_s = time.perf_counter() - t0
                lat.append(adapt_s / users)
                thru.append(users * B * G / (adapt_s + dec_s))
            row[frac_name] = {
                "adapt_p50_us": float(np.percentile(lat, 50) * 1e6),
                "adapt_p99_us": float(np.percentile(lat, 99) * 1e6),
                "adapted_decodes_per_s": float(np.median(thru)),
            }
        sweep[str(users)] = row
        emit(f"serve_users_{users}", row["cold"]["adapt_p50_us"],
             f"cold_p50_us={row['cold']['adapt_p50_us']:.0f};"
             f"cold_p99_us={row['cold']['adapt_p99_us']:.0f};"
             f"recurring_p50_us={row['recurring']['adapt_p50_us']:.0f};"
             f"decodes_per_s_cold={row['cold']['adapted_decodes_per_s']:.1f};"
             f"decodes_per_s_recurring="
             f"{row['recurring']['adapted_decodes_per_s']:.1f}")
    emit("serve_summary", batched * 1e6 / N,
         f"batched_x_cold={x_cold:.1f};cache_hit_x={speedup:.1f};"
         f"drift={drift:.5f};compression={stats['compression']:.2f}x",
         detail={"sweep": sweep})

    if x_cold < 3.0:
        raise RuntimeError(
            f"serve acceptance: batched adapt {x_cold:.2f}x vs "
            f"cold-sequential, pinned >= 3x")
    if speedup < 5.0:
        raise RuntimeError(
            f"serve acceptance: cache hit {speedup:.2f}x vs re-adapt, "
            f"pinned >= 5x")
    if drift > 1e-2:
        raise RuntimeError(
            f"serve acceptance: delta-reconstruction loss drift "
            f"{drift:.4f}, pinned <= 1e-2")


def bench_kernels(quick: bool):
    """Pallas kernels (interpret mode) vs jnp oracles: correctness +
    oracle wall time (kernels target TPU; interpret timing is not a perf
    number, the oracle timing is the CPU reference)."""
    from repro.kernels.dif_combine.dif_combine import dif_combine
    from repro.kernels.dif_combine.ref import dif_combine_ref
    from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    K, M = 16, 1 << 16
    A = jnp.asarray(topology.combination_matrix(K, "ring"), jnp.float32)
    phi = jax.random.normal(jax.random.key(0), (K, M))
    out = dif_combine(A, phi, block_m=512, interpret=True)
    err = float(jnp.max(jnp.abs(out - dif_combine_ref(A, phi))))
    us = _timed(jax.jit(lambda a, p: dif_combine_ref(a, p)), A, phi)
    emit("kernel_dif_combine", us, f"allclose_err={err:.2e};shape={K}x{M}")

    B, H, S, d = 1, 2, 256, 64
    q, k, v = [jax.random.normal(jax.random.key(i), (B, H, S, d))
               for i in range(3)]
    o = flash_attention_fwd(q, k, v, causal=True, block_q=128, block_k=128,
                            interpret=True)
    err = float(jnp.max(jnp.abs(o - attention_ref(q, k, v, causal=True))))
    us = _timed(jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True)),
                q, k, v)
    emit("kernel_flash_attention", us, f"allclose_err={err:.2e};S={S}")

    Bb, L, Hh, P, N = 1, 256, 2, 32, 64
    ks = jax.random.split(jax.random.key(7), 5)
    x = jax.random.normal(ks[0], (Bb, L, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bb, L, Hh))) * 0.5
    Aa = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bb, L, Hh, N)) * 0.3
    Cm = jax.random.normal(ks[4], (Bb, L, Hh, N)) * 0.3
    y, _ = ssd_scan_pallas(x, dt, Aa, Bm, Cm, chunk=64, interpret=True)
    yr, _ = ssd_scan_ref(x, dt, Aa, Bm, Cm)
    err = float(jnp.max(jnp.abs(y - yr)))
    us = _timed(jax.jit(lambda *a: ssd_scan_ref(*a)[0]), x, dt, Aa, Bm, Cm)
    emit("kernel_ssd_scan", us, f"allclose_err={err:.2e};L={L}")


class _LoopLMSource:
    """Legacy python-triple-loop LM sampler adapted to the TaskSource
    surface — the pre-vectorization baseline the pipeline rows measure
    against (also a stand-in for host-bound real-corpus sources)."""

    def __init__(self, sampler, K, T, tb):
        self.sampler = sampler
        self.K, self.tasks_per_agent, self.task_batch = K, T, tb
        self.n_domains = sampler.n_domains
        self.heterogeneity = "domain-shards(loop)"

    def sample(self, step):
        sup, qry = self.sampler.sample_agents(
            self.K, self.tasks_per_agent, self.task_batch, step=step)
        return Episode(sup, qry, step=step)


def bench_pipeline(quick: bool):
    """Tentpole rows: (1) vectorized LM episode generation (one batched
    Markov pass over all K·T·2·tb rows) vs the legacy per-task python
    loop; (2) train-step wall time with synchronous sampling vs the
    background prefetcher, for both the loop and vectorized sources —
    overlap_recovered = fraction of the sync step time the pipeline wins
    back by sampling episode i+1 while the device runs step i."""
    from repro.configs.base import ArchConfig, InputShape
    from repro.data import LMTaskSampler, LMTaskSource
    from repro.launch.mesh import make_host_mesh
    from repro.launch import steps as S

    # Rich Markov domains (4096-bucket × 256-branch transition tables, 8
    # tasks/agent) put episode generation squarely on the host critical
    # path — the regime the pipeline exists for.  The legacy loop rebuilds
    # every table per task; the vectorized source builds each once, caches
    # it, and advances all rows of the step in one generator pass.
    seq, gb = 256, 64
    cfg = ArchConfig(name="lm-pipe-bench", arch_type="dense", num_layers=1,
                     d_model=32, num_heads=2, num_kv_heads=2, head_dim=16,
                     d_ff=64, vocab_size=256, meta_mode="fomaml",
                     topology="ring", outer_optimizer="adam",
                     dtype="float32", remat=False, attn_q_chunk=None,
                     meta_tasks=8)
    shape = InputShape("lm_pipe_bench", seq, gb, "train")
    mesh = make_host_mesh(data=min(4, len(jax.devices())))
    with mesh:
        bundle = S.build_train(cfg, mesh, shape)
        K, T, tb = bundle.K, bundle.T, bundle.tb
        dom_kw = dict(n_domains=8 * max(1, K), branching=256,
                      n_buckets=4096, seed=0)
        vec = LMTaskSource(vocab_size=cfg.padded_vocab, seq_len=seq,
                           K=K, tasks_per_agent=T, task_batch=tb,
                           **dom_kw)
        loop = _LoopLMSource(
            LMTaskSampler(cfg.padded_vocab, seq, **dom_kw), K, T, tb)

        # --- (1) episode generation: vectorized vs python loop -------
        reps = 3 if quick else 10
        vec.sample(0); loop.sample(0)            # warm table caches
        t0 = time.perf_counter()
        for i in range(reps):
            vec.sample(i)
        vec_s = (time.perf_counter() - t0) / reps
        t0 = time.perf_counter()
        for i in range(reps):
            loop.sample(i)
        loop_s = (time.perf_counter() - t0) / reps
        emit("pipeline_lm_vectorized", vec_s * 1e6,
             f"speedup_vs_loop={loop_s / vec_s:.1f}x;"
             f"episodes_per_s={1.0 / vec_s:.1f};"
             f"rows={K * T * 2 * tb};seq={seq}")

        # --- (2) sync vs prefetched trainer input --------------------
        # Two readings per (source, depth):
        #   wall  — end-to-end step wall time (the loop reads the loss
        #           every step, as the production trainer does for
        #           logging; without that read jax's async dispatch
        #           hides sampling in BOTH modes);
        #   stall — time the step loop spends blocked in next(pipe),
        #           i.e. the input path's share of the critical path.
        # The stall is the mechanism metric (prefetch drives it to ~0
        # regardless of machine noise); the wall delta additionally
        # depends on spare host cores, so alternating repetitions are
        # taken and the MEDIAN reported (shared-vCPU clocks drift).
        step = jax.jit(bundle.step_fn, donate_argnums=(0,))
        n_steps = 5 if quick else 8
        n_reps = 3 if quick else 5

        def run(source, depth):
            st = bundle.init_state(seed=0)
            with bundle.make_pipeline(source, depth=depth) as pipe:
                for _ in range(3):               # compile + warm caches
                    st, m = step(st, next(pipe))
                jax.block_until_ready(m["loss"])
                stall = 0.0
                t0 = time.perf_counter()
                for _ in range(n_steps):
                    t1 = time.perf_counter()
                    batch = next(pipe)
                    stall += time.perf_counter() - t1
                    st, m = step(st, batch)
                    float(m["loss"])
                wall = time.perf_counter() - t0
                return wall / n_steps, stall / n_steps

        run(vec, 0)                              # burn-in (first jit run
        # of a fresh process is systematically slower on 2-core CI)

        out = {"sample_us": {"vec": vec_s * 1e6, "loop": loop_s * 1e6},
               "loop": {"sync": [], "prefetch": []},
               "vec": {"sync": [], "prefetch": []}}
        for _ in range(n_reps):
            for label, source in [("loop", loop), ("vec", vec)]:
                out[label]["sync"].append(run(source, 0))
                out[label]["prefetch"].append(run(source, 2))
        med = lambda xs, i: float(np.median([x[i] for x in xs]))
        for label in ["loop", "vec"]:
            raw = out[label]
            out[label] = {
                "sync_us": med(raw["sync"], 0) * 1e6,
                "prefetch_us": med(raw["prefetch"], 0) * 1e6,
                "stall_sync_us": med(raw["sync"], 1) * 1e6,
                "stall_prefetch_us": med(raw["prefetch"], 1) * 1e6,
                "raw": raw,
            }
            o = out[label]
            emit(f"pipeline_overlap_lm_{label}", o["prefetch_us"],
                 f"sync_us={o['sync_us']:.0f};"
                 f"overlap_recovered="
                 f"{(o['sync_us'] - o['prefetch_us']) / o['sync_us']:.3f};"
                 f"input_stall_sync_us={o['stall_sync_us']:.0f};"
                 f"input_stall_prefetch_us={o['stall_prefetch_us']:.0f}")
        emit("pipeline_summary", 0.0,
             "prefetch_faster_than_sync=%s;input_stall_hidden=%.3f;"
             "vectorized_speedup=%.1fx"
             % (out["loop"]["prefetch_us"] < out["loop"]["sync_us"],
                1.0 - out["loop"]["stall_prefetch_us"]
                / max(out["loop"]["stall_sync_us"], 1e-9),
                loop_s / vec_s),
             detail=out)


def bench_generalization_gap(quick: bool):
    """Recurring-vs-unseen generalization (Fallah et al. 2021): meta-train
    Dif-MAML on a sine universe whose top amplitude bands are held out of
    every agent's shard, then report adaptation-loss curves on both splits
    through the same :class:`EvalHarness` the trainer's in-training eval
    hook uses.  ``us_per_call`` = MEDIAN-of-reps wall time of one jitted
    batched adapt-and-measure pass (2-vCPU noise protocol: never trust a
    single timed window)."""
    from repro.eval import EvalHarness

    steps = 150 if quick else 600
    n_tasks = 100 if quick else 200
    source = SineTaskSource(K=6, tasks_per_agent=5, shots=10, n_domains=60,
                            holdout_domains=12, seed=0)
    state, model, _, _ = _sine_train("dif", steps, source=source)
    harness = EvalHarness(model.loss_fn, inner_lr=0.01, inner_steps=5)
    report = harness.evaluate(state, source, n_tasks, seed=1234)

    c = diffusion.centroid(state.params)
    ep = source.eval_sample(n_tasks, seed=1234, split="recurring")
    esup = jax.tree.map(jnp.asarray, ep.support)
    eqry = jax.tree.map(jnp.asarray, ep.query)
    jax.block_until_ready(harness.curves(c, esup, eqry))    # compile
    times = []
    for _ in range(3 if quick else 7):
        t0 = time.perf_counter()
        jax.block_until_ready(harness.curves(c, esup, eqry))
        times.append(time.perf_counter() - t0)
    us = float(np.median(times)) * 1e6

    rec = report.to_record()
    r = rec["splits"]["recurring"]["centroid_curve"]
    u = rec["splits"]["unseen"]["centroid_curve"]
    emit("generalization_gap", us,
         f"recurring_final={r[-1]:.4f};unseen_final={u[-1]:.4f};"
         f"gap={rec['generalization_gap']:.4f};"
         f"disagreement={rec['disagreement']:.2e}",
         detail=rec)


def bench_meta_modes(quick: bool):
    """Exact MAML vs FOMAML vs Reptile on the sine benchmark (paper uses
    exact; the frontier configs use FOMAML — quantify the gap)."""
    steps = 150 if quick else 600
    for mode in ["maml", "fomaml", "reptile"]:
        _, model, curve, us = _sine_train("dif", steps, mode=mode,
                                          lr=1e-3 if mode != "reptile" else 2e-2)
        emit(f"meta_mode_{mode}", us, f"final_test_loss={curve[-1][1]:.4f}")




def bench_mixing(quick: bool):
    """Mixing family: disagreement-decay rate per DiffusionStrategy ×
    TopologySchedule vs the theoretical linear rate of Thm 1.

    For each (topology ∈ {ring, full}) × (schedule ∈ {static,
    link_failure}) × (strategy ∈ {atc, cta, consensus}) the network starts
    from independent inits and the per-step geometric decay of the network
    disagreement over the transient is fitted and compared against λ₂² of
    the (mean) combination matrix — the contraction constant Thm 1
    predicts for one combine.  ``us_per_call`` = MEDIAN wall time of the
    last jitted steps (2-vCPU noise protocol — strategy overhead shows up
    here: cta pays its pre-mix)."""
    from repro.core.meta_trainer import schedule_for

    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    K = 6
    steps = 40 if quick else 150
    fit_n = 8                     # early-transient window for the rate fit
    source = SineTaskSource(K=K, tasks_per_agent=3, shots=10, seed=0)
    out = {}
    for topo in ["ring", "full"]:
        for sched in ["static", "link_failure"]:
            for strat in ["atc", "cta", "consensus"]:
                mcfg = MetaConfig(
                    num_agents=K, tasks_per_agent=3, inner_lr=0.01,
                    outer_optimizer="sgd", outer_lr=1e-3,
                    update_config=UpdateConfig(strategy=strat),
                    topology_config=TopologyConfig(
                        graph=topo, schedule=sched, link_failure_p=0.3,
                        seed=0))
                schedule = schedule_for(mcfg)
                lam2 = schedule.mean_mixing_rate
                state = init_state(jax.random.key(1), model.init, mcfg,
                                   identical_init=False)
                step = jax.jit(make_meta_step(model.loss_fn, mcfg))
                ds = [float(diffusion.disagreement(state.params))]
                times = []
                with MetaBatchPipeline(source, depth=2,
                                       prepare=_DEVICE_EP) as pipe:
                    for i in range(steps):
                        sup, qry = next(pipe)
                        t0 = time.perf_counter()
                        state, m = step(state, sup, qry)
                        if i >= steps - 5:
                            jax.block_until_ready(m["loss"])
                            times.append(time.perf_counter() - t0)
                        ds.append(float(m["disagreement"]))
                us = float(np.median(times)) * 1e6
                rate = float((ds[fit_n] / ds[0]) ** (1.0 / fit_n))
                plateau = float(np.mean(ds[-10:]))
                name = f"mixing_{topo}_{strat}_{sched}"
                out[name] = {"lambda2": lam2, "theory_rate": lam2 ** 2,
                             "decay_rate": rate, "plateau": plateau,
                             "curve": ds}
                emit(name, us,
                     f"decay_rate={rate:.3f};theory_rate={lam2 ** 2:.3f};"
                     f"plateau={plateau:.3e}")
    ring = {s: out[f"mixing_ring_{s}_static"]["decay_rate"]
            for s in ["atc", "cta", "consensus"]}
    lf_slows = (out["mixing_ring_atc_link_failure"]["decay_rate"]
                >= out["mixing_ring_atc_static"]["decay_rate"] - 0.05)
    emit("mixing_summary", 0.0,
         "ring_static_rates=atc:%.3f,cta:%.3f,consensus:%.3f;"
         "link_failure_slows_or_matches=%s" %
         (ring["atc"], ring["cta"], ring["consensus"], lf_slows),
         detail=out)


def bench_topology_ablation(quick: bool):
    """Beyond-paper: Thm 1 makes λ₂ (the mixing rate) the contraction
    constant of the network — sweep topologies at K=16 and relate λ₂ to
    post-training performance and disagreement."""
    from repro.core import init_state, make_meta_step
    steps = 120 if quick else 500
    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    K = 16
    source = SineTaskSource(K=K, tasks_per_agent=3, shots=10, n_domains=64)
    evaln = make_eval_fn(model.loss_fn, inner_lr=0.01, inner_steps=1)
    ep = source.eval_sample(200, seed=999)
    esup = jax.tree.map(jnp.asarray, ep.support)
    eqry = jax.tree.map(jnp.asarray, ep.query)
    out = {}
    for topo in ["full", "torus", "erdos", "ring", "star"]:
        A = topology.combination_matrix(K, topo)
        lam2 = topology.mixing_rate(A)
        mcfg = MetaConfig(num_agents=K, tasks_per_agent=3, inner_lr=0.01,
                          mode="maml", combine="dense", topology=topo,
                          outer_optimizer="adam", outer_lr=1e-3)
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=False)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        with MetaBatchPipeline(source, depth=2, prepare=_DEVICE_EP) as pipe:
            for i in range(steps):
                sup, qry = next(pipe)
                state, m = step(state, sup, qry)
        c = diffusion.centroid(state.params)
        loss = float(np.mean(np.asarray(evaln(c, esup, eqry))[:, 1]))
        dis = float(m["disagreement"])
        deg = int((A[:, 0] > 0).sum() - 1) if topo != "erdos" else             int(np.mean((A > 0).sum(0) - 1))
        out[topo] = {"lambda2": lam2, "loss": loss, "disagreement": dis,
                     "avg_degree": deg}
        emit(f"topology_{topo}", 0.0,
             f"lambda2={lam2:.3f};final_loss={loss:.4f};"
             f"disagreement={dis:.2e};avg_degree={deg}")
    # Thm 1 prediction: plateau disagreement grows with λ₂²/(1−λ₂)²
    ordered = sorted(out, key=lambda t: out[t]["lambda2"])
    mono = all(out[a]["disagreement"] <= out[b]["disagreement"] * 50
               for a, b in zip(ordered, ordered[1:]))
    emit("topology_summary", 0.0,
         f"lambda2_order={'<'.join(ordered)};disagreement_tracks_lambda2={mono}",
         detail=out)


def bench_outer_update(quick: bool):
    """Fused combine-then-update vs the unfused clip→adam→ATC chain:
    HBM bytes/step and wall time at K=8, per param dtype.

    Unfused bytes come from :class:`HloCost` over the compiled step (the
    same trip-count-aware parser the roofline uses); fused bytes are the
    kernel's analytic one-pass contract
    (:func:`repro.launch.hlo_cost.fused_outer_update_bytes`) — interpret-
    mode pallas HLO is emulation scaffolding, not a traffic model, and on
    CPU CI its wall time is emulation-bound too, so the headline derived
    quantity is the bytes ratio with parity pinned by ``max_err``.  The
    acceptance row: bf16 params/grads with fp32 moments — the production
    wire format — must come in at ≤ 0.5× the unfused traffic (f32 lands at
    ≈0.53×: its unfused chain moves relatively less, every buffer already
    being 4-byte)."""
    from repro.core import update
    from repro.core.fused import make_fused_outer
    from repro.launch.hlo_cost import HloCost, fused_outer_update_bytes
    from repro.optim import adam, optimizers as om

    K, S = 8, 1
    M = (1 << 12) if quick else (1 << 15)
    A = jnp.asarray(topology.build_topology("ring", K).matrix)
    lr, b1, b2, eps, clip = 1e-3, 0.9, 0.999, 1e-8, 1.0
    out = {}
    for dtype in (jnp.float32, jnp.bfloat16):
        name = jnp.dtype(dtype).name
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(K, M)), dtype)
        g = jnp.asarray(rng.normal(size=(K, M)), dtype)
        mu = jnp.zeros((K, M), jnp.float32)
        nu = jnp.zeros((K, M), jnp.float32)

        @jax.jit
        def unfused(w, g, mu, nu, t):
            scale = jax.vmap(lambda gk: om.global_norm_scale(gk, clip))(g)
            g = (g * scale[:, None]).astype(g.dtype)
            g32 = g.astype(jnp.float32)
            mu = om.adam_mu(mu, g32, b1)
            nu = om.adam_nu(nu, g32, b2)
            u = om.adam_direction(mu, nu, 1 - b1 ** t, 1 - b2 ** t,
                                  lr=lr, eps=eps)
            phi = w.astype(jnp.float32) + u
            return (jnp.einsum("lk,lm->km", A, phi).astype(w.dtype),
                    mu, nu)

        t1 = jnp.ones((), jnp.float32)
        hlo = unfused.lower(w, g, mu, nu, t1).compile().as_text()
        unfused_bytes = int(HloCost(hlo).bytes_accessed())
        unfused_us = _timed(unfused, w, g, mu, nu, t1)

        outer = make_fused_outer(adam(lr, b1=b1, b2=b2, eps=eps), "atc",
                                 update.CommSchedule(1), np.asarray(A),
                                 grad_clip=clip, num_agents=K)
        st = om.AdamState(jnp.zeros((), jnp.int32), mu, nu)
        step0 = jnp.zeros((), jnp.int32)
        fused = jax.jit(lambda w, g, st, s: outer(w, g, st, s))
        fused_bytes = fused_outer_update_bytes(
            K * M, jnp.dtype(dtype).itemsize, optimizer="adam",
            grad_clip=True)
        fused_us = _timed(fused, w, g, st, step0)

        w_u, mu_u, nu_u = unfused(w, g, mu, nu, t1)
        w_f, st_f = fused(w, g, st, step0)
        max_err = float(jnp.max(jnp.abs(w_f.astype(jnp.float32)
                                        - w_u.astype(jnp.float32))))
        ratio = fused_bytes / unfused_bytes
        out[name] = {"unfused_us": unfused_us, "fused_us": fused_us,
                     "unfused_bytes": unfused_bytes,
                     "fused_bytes": fused_bytes, "ratio": ratio,
                     "max_err": max_err, "K": K, "M": M}
        emit(f"outer_update_{name}", fused_us,
             f"unfused_us={unfused_us:.1f};"
             f"bytes_fused={fused_bytes};bytes_unfused={unfused_bytes};"
             f"bytes_ratio={ratio:.3f};max_err={max_err:.2e};K={K}")
    bf = out["bfloat16"]
    emit("outer_update_summary", 0.0,
         f"bf16_bytes_ratio={bf['ratio']:.3f};"
         f"bf16_within_half={bf['ratio'] <= 0.5};"
         f"f32_bytes_ratio={out['float32']['ratio']:.3f}",
         detail=out)

    # bf16 vs f32 outer storage, end-to-end: 100 sine meta-steps (paper
    # §4.1 harness, same seed and episode stream), meta-loss measured on
    # the f32-cast centroid.  The acceptance row: |drift| ≤ 1e-2 — the
    # parity evidence that bf16 params/grads (with fp32 Adam moments) are
    # safe as the production outer format.
    steps = 100
    curves = {}
    for name, pdt in [("float32", None), ("bfloat16", jnp.bfloat16)]:
        _, _, curve, us = _sine_train("dif", steps, param_dtype=pdt)
        curves[name] = {"curve": curve, "us": us}
    drift = abs(curves["bfloat16"]["curve"][-1][1]
                - curves["float32"]["curve"][-1][1])
    emit("outer_update_bf16_drift", curves["bfloat16"]["us"],
         f"meta_loss_bf16={curves['bfloat16']['curve'][-1][1]:.4f};"
         f"meta_loss_f32={curves['float32']['curve'][-1][1]:.4f};"
         f"drift={drift:.4f};within_tol={drift <= 1e-2};"
         f"steps={steps};K=6", detail=curves)


BENCHES = {
    "fig2b": bench_fig2b_sine_regression,
    "fig2c": bench_fig2c_adaptation_steps,
    "fig3": bench_fig3_fewshot_classification,
    "thm1": bench_thm1_agreement,
    "thm2": bench_thm2_stationarity,
    "combine": bench_combine_strategies,
    "combine_dynamic": bench_combine_dynamic,
    "outer_update": bench_outer_update,
    "superstep": bench_superstep,
    "serve": bench_serve,
    "kernels": bench_kernels,
    "generalization": bench_generalization_gap,
    "modes": bench_meta_modes,
    "pipeline": bench_pipeline,
    "mixing": bench_mixing,
    "topology": bench_topology_ablation,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        fn(args.quick)
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "summary.csv"), "w") as f:
        f.write("name,us_per_call,derived\n")
        for n, u, d in ROWS:
            f.write(f"{n},{u:.1f},{d}\n")


if __name__ == "__main__":
    main()
