"""Roofline analysis (deliverable g).

Reads results/dryrun/*.json (produced by launch/dryrun.py) and derives the
three roofline terms per (arch × input-shape × mesh):

  compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective = wire_bytes_per_device / ICI_link_bw        (50 GB/s/link)

cost_analysis() on the post-SPMD executable reports *per-device* FLOPs and
bytes, so dividing by per-chip peaks is equivalent to the global
``HLO / (chips × peak)`` formulas.  Collective wire bytes come from the
per-op model in launch/dryrun.py::parse_collectives.

Also reports MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), the
useful-compute ratio MODEL/HLO (with the meta-step multiplier called out),
the dominant term, and a one-line "what would move it" note.

  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
      [--csv results/roofline.csv] [--md results/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.models.init import count_params
from repro.models.transformer import build_model

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link


def model_param_counts(arch: str) -> tuple[int, int]:
    """(total, active) parameter counts from the Spec tree."""
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = model.specs()
    total = count_params(specs)
    if not cfg.num_experts:
        return total, total
    # active = total − (inactive experts' share of routed-expert weights)
    import jax
    routed = 0
    for s in jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "axes")):
        if "experts" in s.axes:
            routed += int(np.prod(s.shape))
    frac = 1.0 - cfg.experts_per_token / cfg.num_experts
    return total, int(total - routed * frac)


def expected_meta_multiplier(cfg) -> float:
    """Expected compiled/model compute multiplier of one Dif-MAML meta step
    over a plain train step (6·N·D).  In fwd-units (fwd=1, bwd=2, plain
    step=3) on half-batches each:
      fomaml: inner fwd+bwd (1.5) + outer fwd+bwd (1.5)            ≈ 1.0×
              + per-layer remat recompute (+0.5)                   ≈ 1.2×
      maml:   + jvp-of-grad HVP (≈3.0) + inner-remat re-run (+1.5) ≈ 2.5×
      reptile: inner fwd+bwd (1.5) + query fwd only (0.5) — the outer
              'gradient' is the parameter delta, no outer bwd — (2.0/3
              ≈ 0.67×) + remat recompute                           ≈ 0.8×
    The §Roofline 'useful_ratio' (MODEL/HLO) should therefore sit near
    1/multiplier; large deviations flag redundant compute.
    """
    return {"maml": 2.5, "reptile": 0.8}.get(cfg.meta_mode, 1.2)


def analyze(rec: dict) -> dict:
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    total, active = model_param_counts(arch)

    t_comp = rec["flops_per_device"] / PEAK_FLOPS
    t_mem = rec["bytes_per_device"] / HBM_BW
    t_coll = rec["collectives"]["total_bytes"] / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    if rec["kind"] == "decode":
        tokens = shape.global_batch                      # one token per seq
        model_flops = 2 * active * tokens
        exp_mult = 1.0
    elif rec["kind"] == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * active * tokens                # forward only
        exp_mult = 1.0
    else:
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * active * tokens                # plain train step
        exp_mult = expected_meta_multiplier(cfg)
    hlo_global = rec["flops_per_device"] * rec["devices"]
    ratio = model_flops / hlo_global if hlo_global else float("nan")

    notes = {
        "compute": "raise arithmetic efficiency: fewer recompute passes "
                   "(remat policy), fuse dispatch einsums, larger MXU tiles",
        "memory": "cut HBM traffic: bf16 residuals, flash attention "
                  "(kernels/flash_attention), fewer activation round-trips",
        "collective": "sparser combine schedule (ppermute ring), "
                      "overlap combine with compute, combine_every>1",
    }
    return {
        "arch": arch, "shape": shape_name, "mesh": rec["mesh"],
        "kind": rec["kind"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": ratio,
        "expected_multiplier": exp_mult,
        "params_total": total, "params_active": active,
        "temp_gib": rec["memory"]["temp_size_in_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_size_in_bytes"] / 2**30,
        "coll_ops": rec["collectives"]["total_count"],
        "note": notes[dominant],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--csv", default="results/roofline.csv")
    ap.add_argument("--md", default="results/roofline.md")
    ap.add_argument("--all", action="store_true",
                    help="include HC-tagged experiment files, not just baselines")
    args = ap.parse_args()

    import re as _re
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        base = os.path.basename(path)
        if not args.all and not _re.match(
                r"^[a-z0-9_]+__[a-z0-9_]+__(single|multi)\.json$", base):
            continue
        with open(path) as f:
            rec = json.load(f)
        rows.append(analyze(rec))

    os.makedirs(os.path.dirname(args.csv) or ".", exist_ok=True)
    cols = ["arch", "shape", "mesh", "kind", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "expected_multiplier",
            "params_total", "params_active", "temp_gib", "args_gib",
            "coll_ops", "note"]
    with open(args.csv, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(_fmt(r[c]) for c in cols) + "\n")

    with open(args.md, "w") as f:
        f.write("| arch | shape | mesh | compute s | memory s | collective s"
                " | dominant | MODEL/HLO | temp GiB/dev |\n")
        f.write("|---|---|---|---|---|---|---|---|---|\n")
        for r in rows:
            f.write(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                    f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
                    f"| {r['collective_s']:.3e} | **{r['dominant']}** "
                    f"| {r['useful_ratio']:.2f} | {r['temp_gib']:.1f} |\n")
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"C={r['compute_s']:.2e} M={r['memory_s']:.2e} "
              f"X={r['collective_s']:.2e} -> {r['dominant']:10s} "
              f"useful={r['useful_ratio']:.2f}")
    print(f"\nwrote {args.csv} and {args.md} ({len(rows)} rows)")


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4e}"
    return str(v).replace(",", ";")


if __name__ == "__main__":
    main()
