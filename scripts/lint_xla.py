#!/usr/bin/env python
"""CI gate: lint the pinned production configs' compiled programs.

Lowers each requested arch × agent-mesh train step devicelessly (forced
host devices, AOT compile — no arrays materialized) and runs the full
``repro.analysis`` rule registry over the compiled HLO and traced jaxpr.
Exits non-zero on any finding; writes the JSON report for the CI artifact.

Usage:
  PYTHONPATH=src python scripts/lint_xla.py --arch qwen2-7b --agents 16,8
  PYTHONPATH=src python scripts/lint_xla.py \\
      --arch qwen2-7b,mixtral-8x22b,deepseek-v2-lite-16b \\
      --out results/lint_xla.json
"""

import argparse
import json
import os
import sys

# jax locks the device count at first initialization — these must be set
# before anything imports jax (same contract as launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b",
                    help="comma-separated arch list")
    ap.add_argument("--agents", default="16,8",
                    help="comma-separated agent-mesh extents (16 → 2D "
                         "(agent, model) collapse; 8 → 3D (agent, data, "
                         "model))")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--combine", default="mesh_sparse_dynamic")
    ap.add_argument("--out", default=None,
                    help="write the JSON findings report here")
    args = ap.parse_args()

    from repro.analysis.run import lint_matrix

    archs = [a for a in args.arch.split(",") if a]
    agents = [int(a) for a in args.agents.split(",") if a]
    records, n_findings = lint_matrix(archs, agents, args.shape,
                                      combine=args.combine)
    report = {"ok": n_findings == 0, "n_findings": n_findings,
              "records": records}
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"[lint-xla] report → {args.out}")
    if n_findings:
        print(f"[lint-xla] FAILED: {n_findings} finding(s)")
        return 1
    print(f"[lint-xla] clean: {len(records)} program(s), "
          f"{sum(len(r['lint']['checked']) for r in records)} rule runs")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
