"""CI gate for the trainer's JSONL run log.

Asserts the log is well-formed and that the in-training EvalHarness hook
actually ran: at least one ``kind=eval`` record carrying adaptation-loss
curves for BOTH the recurring and the unseen split, plus a generalization
gap.  Exits non-zero (with a reason) otherwise.

  python scripts/check_run_log.py results/ci_train_eval.jsonl
"""
import json
import sys


def main(path: str) -> None:
    with open(path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    assert records, f"{path} is empty"
    kinds = {r.get("kind") for r in records}
    assert "train" in kinds, f"no train records in {path} (kinds: {kinds})"
    evals = [r for r in records if r.get("kind") == "eval"]
    assert evals, f"no eval records in {path} — was --eval-every set?"
    for rec in evals:
        splits = rec.get("splits", {})
        missing = {"recurring", "unseen"} - set(splits)
        assert not missing, f"eval record at step {rec.get('step')} " \
                            f"missing splits: {missing}"
        for name, s in splits.items():
            curve = s.get("centroid_curve", [])
            assert len(curve) >= 2, \
                f"{name} curve too short (need zero-shot + >=1 step): {curve}"
        assert "generalization_gap" in rec, "missing generalization_gap"
    print(f"ok: {path} has {len(evals)} eval record(s) with both splits "
          f"(last gap: {evals[-1]['generalization_gap']:.4f})")


if __name__ == "__main__":
    main(sys.argv[1])
