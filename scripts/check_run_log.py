"""CI gate for the trainer's JSONL run log.

Asserts the log is well-formed: a ``kind=config`` record that names its
outer-update wiring (``combine_backend`` + the ``fused_outer`` flag — so a
rerun of any logged experiment knows which update path produced it), train
records, and — unless ``--no-eval`` — at least one ``kind=eval`` record
carrying adaptation-loss curves for BOTH the recurring and the unseen
split, plus a generalization gap.  Exits non-zero (with a reason)
otherwise.

  python scripts/check_run_log.py results/ci_train_eval.jsonl
  python scripts/check_run_log.py results/ci_train_fused.jsonl \
      --expect-fused --no-eval
"""
import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="trainer JSONL run log")
    ap.add_argument("--expect-fused", action="store_true",
                    help="require the config record to declare the fused "
                         "one-pass outer update (combine_backend='fused')")
    ap.add_argument("--expect-outer-dtype", default=None,
                    help="require the config record to declare this outer "
                         "storage dtype (e.g. bfloat16)")
    ap.add_argument("--no-eval", action="store_true",
                    help="skip the EvalHarness-record checks (smokes that "
                         "run without --eval-every)")
    ap.add_argument("--expect-analysis", action="store_true",
                    help="require a kind=analysis record (the trainer's "
                         "post-run retrace-guard lint): no findings, and "
                         "jit compile count within the expected budget")
    ap.add_argument("--serve", action="store_true",
                    help="validate a serving run log instead of a trainer "
                         "log: requires a kind=serve record with cache "
                         "hit/miss/eviction counters, adapt latency "
                         "percentiles, and per-phase decode tok/s")
    args = ap.parse_args()
    path = args.path

    with open(path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    assert records, f"{path} is empty"
    kinds = {r.get("kind") for r in records}

    if args.serve:
        serves = [r for r in records if r.get("kind") == "serve"]
        assert serves, f"no serve records in {path} (kinds: {kinds})"
        for rec in serves:
            cache = rec.get("cache", {})
            missing = {"hits", "misses", "evictions", "residents",
                       "compression"} - set(cache)
            assert not missing, \
                f"serve record cache counters missing {missing}: " \
                f"{sorted(cache)}"
            adapt = rec.get("adapt", {})
            assert {"p50_us", "p99_us"} <= set(adapt), \
                f"serve record missing adapt latency percentiles: " \
                f"{sorted(adapt)}"
            decode = rec.get("decode", {})
            assert decode.get("prompt_tok_s") and decode.get("decode_tok_s"), \
                f"serve record missing per-phase decode tok/s: " \
                f"{sorted(decode)}"
        s = serves[-1]
        assert s["cache"]["hits"] >= 1, \
            "serve run never hit the adapted-state cache — the recurring " \
            "fast path was not exercised (run with --rounds >= 2)"
        print(f"ok: {path} has {len(serves)} serve record(s) "
              f"(cache {s['cache']['hits']} hits / {s['cache']['misses']} "
              f"misses, adapt p50 {s['adapt']['p50_us']:.0f}us, "
              f"compression {s['cache']['compression']:.2f}x)")
        return

    assert "train" in kinds, f"no train records in {path} (kinds: {kinds})"

    configs = [r for r in records if r.get("kind") == "config"]
    assert configs, f"no config record in {path} (kinds: {kinds})"
    for rec in configs:
        assert "fused_outer" in rec and "combine_backend" in rec, \
            f"config record missing outer-update provenance " \
            f"(fused_outer/combine_backend): {sorted(rec)}"
        assert "outer_dtype" in rec and "combine_dtype" in rec, \
            f"config record missing numerics provenance " \
            f"(outer_dtype/combine_dtype): {sorted(rec)}"
    if args.expect_outer_dtype:
        assert all(r["outer_dtype"] == args.expect_outer_dtype
                   for r in configs), \
            f"--expect-outer-dtype {args.expect_outer_dtype} but config " \
            f"records say {[r['outer_dtype'] for r in configs]}"
    if args.expect_fused:
        assert all(r["fused_outer"] and r["combine_backend"] == "fused"
                   for r in configs), \
            f"--expect-fused but config records say " \
            f"{[(r['combine_backend'], r['fused_outer']) for r in configs]}"

    if args.expect_analysis:
        analyses = [r for r in records if r.get("kind") == "analysis"]
        assert analyses, \
            f"--expect-analysis but no analysis record in {path} " \
            f"(kinds: {kinds})"
        for rec in analyses:
            assert rec.get("ok"), \
                f"analysis record has findings: {rec.get('findings')}"
            assert "retrace-guard" in rec.get("checked", []), \
                f"analysis record did not run retrace-guard: " \
                f"{rec.get('checked')}"
            compiles = rec.get("jit_compiles")
            # None = a jax build without a readable jit cache size; the
            # jaxpr-level checks above still gate the record
            if compiles is not None:
                assert compiles <= rec["expected_compiles"], \
                    f"superstep compiled {compiles}x, expected at most " \
                    f"{rec['expected_compiles']} (over " \
                    f"{rec.get('dispatches')} dispatches)"
        a = analyses[-1]
        print(f"ok: {path} analysis record clean "
              f"(compiles={a.get('jit_compiles')}/"
              f"{a.get('expected_compiles')}, "
              f"checked={a.get('checked')})")

    if args.no_eval:
        print(f"ok: {path} has {len(configs)} config record(s) "
              f"(backend={configs[-1]['combine_backend']}, "
              f"fused_outer={configs[-1]['fused_outer']}, "
              f"outer_dtype={configs[-1]['outer_dtype']}, "
              f"combine_dtype={configs[-1]['combine_dtype']}) "
              f"and train records")
        return
    evals = [r for r in records if r.get("kind") == "eval"]
    assert evals, f"no eval records in {path} — was --eval-every set?"
    for rec in evals:
        splits = rec.get("splits", {})
        missing = {"recurring", "unseen"} - set(splits)
        assert not missing, f"eval record at step {rec.get('step')} " \
                            f"missing splits: {missing}"
        for name, s in splits.items():
            curve = s.get("centroid_curve", [])
            assert len(curve) >= 2, \
                f"{name} curve too short (need zero-shot + >=1 step): {curve}"
        assert "generalization_gap" in rec, "missing generalization_gap"
    print(f"ok: {path} has {len(evals)} eval record(s) with both splits "
          f"(last gap: {evals[-1]['generalization_gap']:.4f})")


if __name__ == "__main__":
    main()
