"""Regenerate the data tables inside EXPERIMENTS.md from results/.

  PYTHONPATH=src python scripts/fill_experiments.py
"""
import glob
import json
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
ROOT = os.path.join(os.path.dirname(__file__), "..")


def dryrun_table() -> str:
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, "results/dryrun/*.json"))):
        # baselines only: <arch>__<shape>__single.json (HC-tagged variants
        # carry extra __ suffixes and live in §Perf)
        if not re.match(r"^[a-z0-9_]+__[a-z0-9_]+__single\.json$",
                        os.path.basename(path)):
            continue
        with open(path) as f:
            r = json.load(f)
        rows.append(r)
    out = ["| arch | shape | K | FLOPs/dev | HBM B/dev | wire B/dev (ops) "
           "| temp GiB/dev | args GiB/dev | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        r["arch"] = r["arch"].replace("-", "_").replace(".", "_")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        k = r.get("num_agents", "—")
        out.append(
            f"| {r['arch']} | {r['shape']} | {k} "
            f"| {r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} "
            f"| {r['collectives']['total_bytes']:.2e} ({r['collectives']['total_count']}) "
            f"| {r['memory']['temp_size_in_bytes']/2**30:.1f} "
            f"| {r['memory']['argument_size_in_bytes']/2**30:.2f} "
            f"| {r['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table(top_n: int = 12) -> str:
    path = os.path.join(ROOT, "results/roofline.csv")
    if not os.path.exists(path):
        return "(run benchmarks.roofline first)"
    lines = open(path).read().strip().splitlines()
    hdr = lines[0].split(",")
    recs = [dict(zip(hdr, l.split(","))) for l in lines[1:]]
    recs.sort(key=lambda r: -max(float(r["compute_s"]), float(r["memory_s"]),
                                 float(r["collective_s"])))
    out = ["| arch | shape | mesh | compute s | memory s | collective s "
           "| dominant | MODEL/HLO |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs[:top_n]:
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                   f"| {float(r['compute_s']):.2e} | {float(r['memory_s']):.2e} "
                   f"| {float(r['collective_s']):.2e} | **{r['dominant']}** "
                   f"| {float(r['useful_ratio']):.2f} |")
    out.append(f"\n(top {top_n} by largest term; full table in "
               "results/roofline.md)")
    return "\n".join(out)


def bench_table() -> str:
    path = os.path.join(ROOT, "results/benchmarks/summary.csv")
    if not os.path.exists(path):
        return "(run benchmarks.run first)"
    lines = open(path).read().strip().splitlines()[1:]
    out = ["| bench | us/call | derived |", "|---|---|---|"]
    for l in lines:
        name, us, derived = l.split(",", 2)
        out.append(f"| {name} | {float(us):.0f} | `{derived}` |")
    return "\n".join(out)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()

    def repl(marker: str, content: str, text: str) -> str:
        pat = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\n### |\Z)", re.S)
        block = f"<!-- {marker} -->\n{content}\n"
        if pat.search(text):
            return pat.sub(lambda m: block, text, count=1)
        return text

    text = repl("DRYRUN_TABLE", dryrun_table(), text)
    text = repl("ROOFLINE_TABLE", roofline_table(), text)
    text = repl("BENCH_TABLE", bench_table(), text)
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables refreshed")


if __name__ == "__main__":
    main()
