import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape) combination against the
production meshes — 16×16 single-pod, 2×16×16 two-pod, and the agent-axis
meshes of ``make_production_mesh(agents=K)`` — and records memory analysis,
HLO FLOPs/bytes, and the per-device collective schedule (parsed from the
post-SPMD HLO) for the roofline analysis.

The two lines above MUST stay the first statements in this module: jax locks
the device count at first initialization, and only the dry-run wants 512
placeholder host devices.

With ``--agents K`` the train step is validated on the 2D/3D agent mesh:
the per-device parameter-shard size and the schedule degree give the exact
wire budget the sparse combine must hit — deg·shard collective-permute
bytes, NOT K·shard — and ``--assert-budgets`` enforces it plus the pinned
per-config total-collective ceilings in :data:`AGENT_MESH_BUDGETS` (the
production-scale sibling of tests/test_hlo_cost.py's deg-not-K pin).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k \\
      --agents 16 --combine mesh_sparse_dynamic --assert-budgets
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.launch import steps as S

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(ls: str, n_dev: int) -> int:
    m = _GROUPS_IOTA_RE.search(ls)
    if m:  # [n_groups, group_size]<=[...]
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(ls)
    if m:
        return max(1, len(m.group(1).split(",")))
    return n_dev


def parse_collectives(hlo: str, n_dev: int) -> dict:
    """Per-device wire bytes for every collective in post-SPMD HLO.

    Result shapes in the HLO are per-device shards.  Wire-byte model per op
    (ring algorithms, group size K):
      all-gather          result · (K−1)/K
      reduce-scatter      result · (K−1)          (operand = result·K)
      all-reduce          result · 2(K−1)/K       (RS + AG)
      all-to-all          result · (K−1)/K
      collective-permute  result                  (point-to-point)
    """
    per_op: dict[str, dict] = {}
    biggest: list[tuple[int, str]] = []
    for line in hlo.splitlines():
        ls = line.strip()
        m_op = re.search(r"= [^ ]+ ([a-z\-]+)(?:-start)?\(", ls)
        if not m_op:
            continue
        op = m_op.group(1).removesuffix("-start")
        if op not in COLLECTIVE_OPS or "-done(" in ls:
            continue
        head = ls.split("=", 1)[1]
        head = head[: head.index("(")]
        result_bytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(head))
        K = _group_size(ls, n_dev)
        if op == "all-gather":
            wire = result_bytes * (K - 1) // K
        elif op == "reduce-scatter":
            wire = result_bytes * (K - 1)
        elif op == "all-reduce":
            wire = result_bytes * 2 * (K - 1) // K
        elif op == "all-to-all":
            wire = result_bytes * (K - 1) // K
        else:  # collective-permute
            wire = result_bytes
        d = per_op.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0})
        d["count"] += 1
        d["bytes"] += result_bytes
        d["wire_bytes"] += wire
        biggest.append((wire, ls[:200]))
    biggest.sort(key=lambda t: -t[0])
    return {"per_op": per_op,
            "total_bytes": sum(d["wire_bytes"] for d in per_op.values()),
            "total_count": sum(d["count"] for d in per_op.values()),
            "top": [{"bytes": b, "op": s} for b, s in biggest[:8]]}


def _mem_dict(mem) -> dict:
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    return {f: getattr(mem, f) for f in fields}


# The pinned agent-mesh budgets moved to repro.analysis.run (the lint
# driver owns every compiled-program invariant); re-exported here for the
# existing consumers of this module's surface.
from repro.analysis.run import AGENT_MESH_BUDGETS  # noqa: E402,F401


def _mesh_tag(mesh, multi_pod: bool, agents: int | None) -> str:
    if agents is None:
        return "2x16x16" if multi_pod else "16x16"
    return "x".join(f"{name[0]}{size}" for name, size in
                    zip(mesh.axis_names, mesh.devices.shape))


def run_one(arch: str, shape_name: str, multi_pod: bool,
            combine: str | None = None, save_hlo: str | None = None,
            overrides: dict | None = None, agents: int | None = None,
            assert_budgets: bool = False) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, agents=agents)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            bundle = S.build_train(cfg, mesh, shape_name,
                                   combine_override=combine)
            # out_shardings pins the NEW state to the same layout as the
            # input state — without it XLA may emit a step whose output
            # sharding differs (hiding the combine's data movement from
            # this step and pushing it into the next one)
            jitted = jax.jit(bundle.step_fn,
                             in_shardings=(bundle.state_shardings,
                                           bundle.batch_shardings),
                             out_shardings=(bundle.state_shardings, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(bundle.state_specs,
                                   S.input_specs(cfg, shape_name))
            extra = {"num_agents": bundle.K, "tasks_per_agent": bundle.T,
                     "task_batch": bundle.tb}
        elif shape.kind == "prefill":
            bundle = S.build_prefill(cfg, mesh, shape_name)
            jitted = jax.jit(bundle.step_fn,
                             in_shardings=(bundle.params_shardings,
                                           bundle.batch_shardings))
            lowered = jitted.lower(bundle.params_specs,
                                   S.input_specs(cfg, shape_name))
            extra = {}
        else:  # decode
            bundle = S.build_serve(cfg, mesh, shape_name)
            ins = S.input_specs(cfg, shape_name)
            jitted = jax.jit(
                bundle.step_fn,
                in_shardings=(bundle.params_shardings,
                              bundle.input_shardings["cache"],
                              bundle.input_shardings["token"],
                              bundle.input_shardings["pos"]),
                donate_argnums=(1,))
            lowered = jitted.lower(bundle.params_specs, ins["cache"],
                                   ins["token"], ins["pos"])
            extra = {}
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    from repro.compat import cost_analysis as _cost_analysis
    cost = _cost_analysis(compiled)
    hlo = compiled.as_text()
    n_dev = int(np.prod(mesh.devices.shape))
    # cost_analysis() counts while-loop bodies once (ignores trip counts) —
    # fatal for layer-scanned models, including their in-scan collectives.
    # hlo_cost re-derives flops/bytes/collectives with known_trip_count
    # applied (see launch/hlo_cost.py).
    from repro.launch.hlo_cost import corrected_costs
    corr = corrected_costs(hlo, n_dev=n_dev)
    coll = corr["collectives"]
    coll["top_level_only"] = parse_collectives(hlo, n_dev)["per_op"]
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_tag(mesh, multi_pod, agents),
        "devices": n_dev,
        "kind": shape.kind,
        "combine": combine or cfg.combine,
        "flops_per_device": corr["flops"],
        "bytes_per_device": corr["bytes"],
        "flops_raw_cost_analysis": cost.get("flops", 0.0),
        "bytes_raw_cost_analysis": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "memory": _mem_dict(mem),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        **extra,
    }
    if agents is not None and shape.kind == "train":
        # Delegate every compiled-program invariant to the lint registry
        # (repro.analysis) — the deg·shard permute window, the bf16→u16
        # wire check, and the pinned per-config collective ceiling all
        # live there now; this block only reports and (under
        # --assert-budgets) raises on findings.
        from repro.analysis.rules import run_rules
        from repro.analysis.run import context_for_bundle
        ceiling = AGENT_MESH_BUDGETS.get((arch, shape_name, agents))
        ctx = context_for_bundle(bundle, hlo, ceiling=ceiling)
        report = run_rules(ctx,
                           only=["collective-budget", "wire-dtype-leak"])
        budget = report.records["collective-budget"]
        rec["combine_budget"] = budget
        rec["lint"] = report.to_json()
        wire, deg = bundle.combine_dtype, budget["degree"]
        print(f"  combine_budget: deg={deg} × shard "
              f"{budget['param_shard_bytes']:.3e} B "
              f"({wire} wire) → permute {budget['permute_bytes']:.3e} B "
              f"({'ok' if report.ok else 'VIOLATION'}), "
              f"total coll {budget['total_collective_bytes']:.3e} B")
        if assert_budgets and not report.ok:
            raise AssertionError(
                f"{arch} × {shape_name} × {rec['mesh']}: " +
                "; ".join(f.message for f in report.findings))
    print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}"
          f" ok: {rec['flops_per_device']:.3e} flops/dev,"
          f" {rec['bytes_per_device']:.3e} B/dev,"
          f" coll {coll['total_bytes']:.3e} B/dev ({coll['total_count']} ops),"
          f" temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev,"
          f" args {mem.argument_size_in_bytes/2**30:.2f} GiB/dev,"
          f" compile {rec['compile_s']:.0f}s")
    print("  memory_analysis:", _mem_dict(mem))
    print("  cost_analysis: flops=%.4g bytes=%.4g" %
          (rec["flops_per_device"], rec["bytes_per_device"]))
    return rec


def shapes_for(arch: str) -> list[str]:
    """long_500k only for sub-quadratic archs (DESIGN.md §long_500k skips);
    decode skipped for encoder-only archs (none assigned)."""
    cfg = get_config(arch)
    sub_quadratic = (cfg.arch_type in ("ssm", "hybrid")
                     or cfg.sliding_window is not None)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if sub_quadratic:
        out.append("long_500k")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--agents", type=int, default=None,
                    help="build the agent-axis production mesh "
                         "make_production_mesh(agents=K) — (agent, data, "
                         "model), collapsing to 2D (agent, model) — instead "
                         "of the legacy placement-driven meshes")
    ap.add_argument("--combine", default=None,
                    help="combine backend override: 'auto' or any "
                         "repro.core.diffusion.combine_backends() name "
                         "(dense | sparse | sparse_host | mesh_sparse | "
                         "sparse_dynamic | sparse_host_dynamic | "
                         "mesh_sparse_dynamic | pallas | centralized | none)")
    ap.add_argument("--assert-budgets", action="store_true",
                    help="fail if the agent-mesh combine leaves the "
                         "deg·shard collective-permute window or a config "
                         "exceeds its pinned AGENT_MESH_BUDGETS ceiling")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--hvp-subsample", type=float, default=None)
    ap.add_argument("--attn-q-chunk", type=int, default=None)
    ap.add_argument("--inner-freeze", default=None)
    ap.add_argument("--attn-shard", default=None)
    ap.add_argument("--inner-steps", type=int, default=None)
    ap.add_argument("--tag", default=None, help="suffix for output json")
    args = ap.parse_args()
    overrides = {}
    if args.hvp_subsample is not None:
        overrides["hvp_subsample"] = args.hvp_subsample
    if args.attn_q_chunk is not None:
        overrides["attn_q_chunk"] = args.attn_q_chunk
    if args.inner_freeze is not None:
        overrides["inner_freeze"] = args.inner_freeze
    if args.attn_shard is not None:
        overrides["attn_shard"] = args.attn_shard
    if args.inner_steps is not None:
        overrides["inner_steps"] = args.inner_steps

    os.makedirs(args.out, exist_ok=True)
    archs = list_archs() if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for arch in archs:
        shapes = shapes_for(arch) if args.shape == "all" else [args.shape]
        for shape in shapes:
            for mp in meshes:
                mesh_part = (f"agent{args.agents}" if args.agents
                             else ("multi" if mp else "single"))
                tag = f"{arch.replace('-', '_').replace('.', '_')}__{shape}__{mesh_part}"
                if args.combine:
                    tag += f"__{args.combine}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_one(arch, shape, mp, combine=args.combine,
                                  save_hlo=args.save_hlo, overrides=overrides,
                                  agents=args.agents,
                                  assert_budgets=args.assert_budgets)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                except Exception as e:  # record and continue
                    failures.append((tag, repr(e)))
                    print(f"[dryrun] FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-runs passed")


if __name__ == "__main__":
    main()
