"""Dif-MAML training driver.

Runs the decentralized meta-training loop for any registered architecture.
On real TPU slices this uses the production mesh; on CPU it falls back to a
reduced config + host mesh so the same entrypoint exercises end-to-end.

Every run emits a JSONL run log (``--run-log``, default
``results/train_<arch>_seed<seed>.jsonl``): one ``{"kind": "train", ...}``
record per logged step and — with ``--eval-every`` — one
``{"kind": "eval", ...}`` record per :class:`~repro.eval.EvalHarness` pass,
carrying the recurring-vs-unseen adaptation-loss curves, the generalization
gap, and disagreement-at-eval.  Benchmarks and plots consume the log
instead of scraping stdout.  Train records carry ``step_time_s`` (per-step
train-compute wall of the dispatch that produced them, excluding eval/
checkpoint/log time) next to the cumulative wall-clock ``time_s``.

The hot loop is a *superstep* driver: ``--steps-per-dispatch C`` runs C
meta-steps inside one jitted, buffer-donated ``lax.scan`` call
(:func:`repro.launch.steps.make_superstep`) with the pipeline stacking C
meta-batches per dispatch and metrics accumulated on device — one Python
dispatch and one host fetch per C steps, so fast hardware is no longer
dispatch-bound.  Log/eval/checkpoint cadences align to dispatch
boundaries; C=1 reproduces the legacy per-step loop step-for-step.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 20 \\
      --reduced --seq 64 --global-batch 16 --agents 4 --seed 1 \\
      --eval-every 10 --eval-tasks 8
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax

from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.configs import INPUT_SHAPES, get_config, register_input_shape
from repro.configs.base import InputShape
from repro.core import diffusion, topology, update
from repro.data.lm_tasks import LMTaskSource
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch import steps as S


def make_train_source(cfg, shape, K: int, T: int, tb: int, seed: int = 0,
                      holdout_domains: int | None = None) -> LMTaskSource:
    """The production trainer's task stream: per-agent heterogeneous LM
    domain shards (the paper's π_k).  Replaces the old ``make_batch``,
    which sampled ONE domain for the entire global batch — every agent was
    secretly training on the same distribution.

    On top of the trained universe, ``holdout_domains`` extra domains
    (default ``max(2, K // 2)``) are appended and held out of every agent's
    shard — the unseen split the in-training EvalHarness measures against.
    """
    n_train = max(8, 4 * K)
    holdout = max(2, K // 2) if holdout_domains is None else holdout_domains
    return LMTaskSource(
        vocab_size=cfg.padded_vocab, seq_len=shape.seq_len,
        K=K, tasks_per_agent=T, task_batch=tb,
        n_domains=n_train + holdout, holdout_domains=holdout, seed=seed)


class RunLog:
    """JSONL writer, one flushed record per line.  ``resume=True`` appends
    (a checkpoint-resumed run continues its existing log); otherwise the
    file restarts with the run."""

    def __init__(self, path: str, resume: bool = False):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a" if resume else "w")

    def write(self, **record) -> None:
        self._f.write(json.dumps(record) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0,
                    help="run seed: threads through launch-model init, the "
                         "task source, and checkpoint naming (ckpt-dir/"
                         "seed<N>/) so independent runs never collide")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--eval-every", type=int, default=0,
                    help="run the recurring-vs-unseen EvalHarness every n "
                         "steps (0 = off); results go to the run log")
    ap.add_argument("--eval-tasks", type=int, default=8,
                    help="eval tasks drawn per split per harness pass")
    ap.add_argument("--eval-inner-steps", type=int, default=3,
                    help="adaptation steps measured by the eval harness "
                         "(curves have this + 1 entries; index 0 = 0-shot)")
    ap.add_argument("--run-log", default=None,
                    help="JSONL run log path (default results/"
                         "train_<arch>_seed<seed>.jsonl)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh-agents", type=int, default=None,
                    help="build an agent-axis mesh (agent[, data], model) "
                         "with this many agents instead of the legacy "
                         "placement-driven meshes; each agent's parameter "
                         "slice is itself TP/FSDP-sharded. With --reduced "
                         "the host-mesh equivalent is built over the "
                         "available devices (count must be divisible by "
                         "the agent count)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="meta-batch pipeline depth (0 = sample "
                         "synchronously on the step loop)")
    ap.add_argument("--steps-per-dispatch", type=int, default=1,
                    help="meta-steps per jitted dispatch (lax.scan "
                         "superstep): one Python dispatch + one host "
                         "metric fetch per C steps; log/eval/ckpt "
                         "cadences align to dispatch boundaries. Pick "
                         "--steps divisible by C to avoid one extra "
                         "compile for the final partial dispatch")
    ap.add_argument("--combine", default=None,
                    help="combine backend override: 'auto' or any "
                         "diffusion.combine_backends() name")
    ap.add_argument("--strategy", default=None,
                    choices=sorted(update.update_strategies()),
                    help="outer-update composition (default atc, paper "
                         "Algorithm 1): how the combine composes with the "
                         "local meta-update")
    ap.add_argument("--topology-schedule", default="static",
                    choices=sorted(topology.SCHEDULES),
                    help="per-step communication-graph schedule over the "
                         "arch's topology")
    ap.add_argument("--link-failure-p", type=float, default=0.2,
                    help="i.i.d. per-edge drop probability for "
                         "--topology-schedule link_failure")
    ap.add_argument("--fused-outer", action="store_true",
                    help="run the one-pass combine-then-update outer step "
                         "(shorthand for --combine fused): clip scale, "
                         "optimizer moments and launch-model mix in a "
                         "single kernel sweep over the parameter bytes")
    ap.add_argument("--outer-dtype", default=None,
                    choices=sorted(S.DTYPES),
                    help="params/grads storage dtype for the outer loop "
                         "(Adam moments stay fp32); defaults to the arch's "
                         "dtype")
    ap.add_argument("--combine-dtype", default=None,
                    choices=sorted(diffusion.WIRE_DTYPES),
                    help="combine wire format for the ppermute backends; "
                         "defaults to bfloat16 when the outer dtype is "
                         "bfloat16 (f32 escape hatch: --combine-dtype "
                         "float32)")
    args = ap.parse_args()
    if args.fused_outer:
        if args.combine not in (None, "fused"):
            ap.error(f"--fused-outer conflicts with --combine "
                     f"{args.combine}: the fused outer step IS the combine "
                     f"backend")
        args.combine = "fused"

    cfg = get_config(args.arch)
    if args.outer_dtype or args.combine_dtype:
        cfg = dataclasses.replace(
            cfg, outer_dtype=args.outer_dtype or cfg.outer_dtype,
            combine_dtype=args.combine_dtype or cfg.combine_dtype)
    if args.reduced:
        cfg = cfg.reduced()
        shape = InputShape("custom", args.seq, args.global_batch, "train")
        if args.mesh_agents:
            # host-scale agent mesh: spend the leftover device factor on TP
            mesh = make_host_mesh(
                model=max(1, len(jax.devices()) // args.mesh_agents),
                agents=args.mesh_agents)
        else:
            mesh = make_host_mesh(data=args.agents)
        # registered (not assigned) so an in-process rerun with a different
        # geometry replaces the entry loudly instead of leaking state
        register_input_shape(shape, override=True)
        shape_name = shape.name
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod,
                                    agents=args.mesh_agents)
        shape_name = args.shape
        shape = INPUT_SHAPES[shape_name]

    ckpt_dir = (os.path.join(args.ckpt_dir, f"seed{args.seed}")
                if args.ckpt_dir else None)
    resuming = ckpt_dir is not None and latest_step(ckpt_dir) is not None
    log_path = args.run_log or os.path.join(
        "results", f"train_{cfg.name}_seed{args.seed}.jsonl")
    run_log = RunLog(log_path, resume=resuming)

    with mesh:
        bundle = S.build_train(cfg, mesh, shape_name,
                               combine_override=args.combine,
                               strategy=args.strategy,
                               schedule=args.topology_schedule,
                               link_failure_p=args.link_failure_p,
                               schedule_seed=args.seed)
        ucfg = bundle.mcfg.update_config
        sched = bundle.schedule
        print(f"[train] {cfg.name}: K={bundle.K} agents, "
              f"T={bundle.T} tasks × {bundle.tb} examples, "
              f"mode={ucfg.inner}, seed={args.seed}")
        if sched is not None:
            print(f"[train] outer update: strategy={ucfg.strategy} over "
                  f"'{sched.topology.name}' ({sched.kind} schedule, "
                  f"period {sched.period}, "
                  f"mean λ₂={sched.mean_mixing_rate:.3f}), "
                  f"combine_every={ucfg.combine_every}")
        state = bundle.init_state(seed=args.seed)
        if resuming:
            state = restore_checkpoint(ckpt_dir, state)
            print(f"[train] restored step {int(state.step)}")
        C = max(1, args.steps_per_dispatch)
        # Commit the state to its steady-state shardings up front and pin
        # the step output to the same layout: an uncommitted init state
        # compiles the superstep once with unspecified input layouts, then
        # the committed state it returns forces a second compile of the
        # identical program — retrace-guard counts that as a cache miss.
        state = jax.device_put(state, bundle.state_shardings)
        superstep_fn = jax.jit(S.make_superstep(bundle.step_fn),
                               donate_argnums=(0,),
                               out_shardings=(bundle.state_shardings, None))
        source = make_train_source(cfg, shape, bundle.K, bundle.T, bundle.tb,
                                   seed=args.seed)
        print(f"[train] task source: {source.n_train_domains} domains "
              f"(+{source.holdout_domains} held out), "
              f"{source.heterogeneity} over K={bundle.K} agents, "
              f"prefetch depth {args.prefetch}")
        harness = prepare = None
        if args.eval_every:
            harness = bundle.make_eval_harness(args.eval_inner_steps)
            prepare = bundle.eval_prepare()
            print(f"[train] eval hook: recurring-vs-unseen, "
                  f"{args.eval_tasks} tasks × {args.eval_inner_steps} "
                  f"adaptation steps every {args.eval_every} steps "
                  f"-> {log_path}")
        run_log.write(kind="config", arch=cfg.name, seed=args.seed,
                      mesh_axes={n: int(s) for n, s in
                                 zip(mesh.axis_names, mesh.devices.shape)},
                      K=bundle.K, T=bundle.T, tb=bundle.tb,
                      mode=ucfg.inner, strategy=ucfg.strategy,
                      combine_backend=ucfg.backend,
                      fused_outer=ucfg.backend == "fused",
                      outer_dtype=bundle.outer_dtype,
                      combine_dtype=bundle.combine_dtype,
                      topology_schedule=args.topology_schedule,
                      link_failure_p=(args.link_failure_p
                                      if args.topology_schedule
                                      == "link_failure" else None),
                      steps=args.steps, steps_per_dispatch=C,
                      n_domains=source.n_domains,
                      holdout_domains=source.holdout_domains)
        t0 = time.time()
        train_wall = 0.0       # train-compute only: excludes eval/ckpt/log
        done = 0
        with bundle.make_pipeline(source, depth=args.prefetch,
                                  start_step=int(state.step),
                                  stack=C) as pipe:
            while done < args.steps:
                n = min(C, args.steps - done)
                batch = next(pipe)
                if n < C:      # final partial dispatch (one extra compile)
                    batch = {k: v[:n] for k, v in batch.items()}
                td = time.perf_counter()
                state, metrics = superstep_fn(state, batch)
                # ONE host sync per dispatch: the (n,)-shaped step-resolved
                # metric arrays come back in a single fetch
                m = jax.device_get(metrics)
                dispatch_s = time.perf_counter() - td
                train_wall += dispatch_s
                base, done = done, done + n
                last_step = int(state.step)       # one fetch per dispatch
                for j in range(n):
                    if (base + j) % args.log_every == 0:
                        step_no = last_step - n + j + 1
                        loss = float(m["loss"][j])
                        dis = float(m["disagreement"][j])
                        print(f"step {step_no:5d} "
                              f"loss {loss:.4f} "
                              f"disagreement {dis:.3e} "
                              f"({time.time() - t0:.1f}s)")
                        run_log.write(kind="train", step=step_no,
                                      loss=loss, disagreement=dis,
                                      time_s=round(time.time() - t0, 3),
                                      step_time_s=round(dispatch_s / n, 6),
                                      train_time_s=round(train_wall, 3))
                if harness is not None and (
                        base // args.eval_every < done // args.eval_every
                        or done >= args.steps):
                    report = harness.evaluate(state, source, args.eval_tasks,
                                              prepare=prepare)
                    rec = report.to_record()
                    run_log.write(kind="eval", **rec)
                    rc = rec["splits"]["recurring"]["centroid_curve"]
                    uc = rec["splits"]["unseen"]["centroid_curve"]
                    print(f"[eval] step {int(state.step)} "
                          f"recurring {rc[0]:.3f}->{rc[-1]:.3f} "
                          f"unseen {uc[0]:.3f}->{uc[-1]:.3f} "
                          f"gap {rec['generalization_gap']:.4f}")
                if ckpt_dir and (base // args.ckpt_every
                                 < done // args.ckpt_every):
                    save_checkpoint(ckpt_dir, int(state.step), state)
        if ckpt_dir:
            save_checkpoint(ckpt_dir, int(state.step), state)
        # Post-run compiled-program lint (repro.analysis): retrace-guard
        # checks the traced step for weak-type python scalars and host
        # callbacks, and asserts the superstep driver compiled exactly
        # once per batch shape — 1, plus 1 more only when a final partial
        # dispatch (steps % C != 0) forced a second shape.  The record
        # lands in the run log for check_run_log.py --expect-analysis.
        from repro.analysis.rules import CompileCounter, run_rules
        from repro.analysis.run import context_for_bundle
        dispatches = -(-args.steps // C)
        expected_compiles = 1 + (1 if args.steps % C else 0)
        compiles = CompileCounter(superstep_fn).count()
        try:
            jaxpr = jax.make_jaxpr(bundle.step_fn)(
                bundle.state_specs, S.input_specs(cfg, shape_name))
        except Exception:
            jaxpr = None  # best-effort: compile counts still checked
        ctx = context_for_bundle(
            bundle, jaxpr=jaxpr,
            compile_counts={"superstep": {"compiles": compiles,
                                          "expected": expected_compiles,
                                          "dispatches": dispatches}})
        report = run_rules(ctx, only=["retrace-guard"])
        run_log.write(kind="analysis", **report.to_json(),
                      jit_compiles=compiles,
                      expected_compiles=expected_compiles,
                      dispatches=dispatches)
        if not report.ok:
            for f in report.findings:
                print(f"[analysis] FINDING[{f.rule}] {f.message}")
    run_log.close()
    print(f"[train] done (run log: {log_path})")


if __name__ == "__main__":
    main()
