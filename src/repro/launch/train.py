"""Dif-MAML training driver.

Runs the decentralized meta-training loop for any registered architecture.
On real TPU slices this uses the production mesh; on CPU it falls back to a
reduced config + host mesh so the same entrypoint exercises end-to-end.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 20 \\
      --reduced --seq 64 --global-batch 16 --agents 4
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape
from repro.data.lm_tasks import LMTaskSource
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch import steps as S


def make_train_source(cfg, shape, K: int, T: int, tb: int,
                      seed: int = 0) -> LMTaskSource:
    """The production trainer's task stream: per-agent heterogeneous LM
    domain shards (the paper's π_k).  Replaces the old ``make_batch``,
    which sampled ONE domain for the entire global batch — every agent was
    secretly training on the same distribution."""
    return LMTaskSource(
        vocab_size=cfg.padded_vocab, seq_len=shape.seq_len,
        K=K, tasks_per_agent=T, task_batch=tb,
        n_domains=max(8, 4 * K), seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant (CPU)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="meta-batch pipeline depth (0 = sample "
                         "synchronously on the step loop)")
    ap.add_argument("--combine", default=None,
                    help="combine backend override: 'auto' or any "
                         "diffusion.combine_backends() name")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        shape = InputShape("custom", args.seq, args.global_batch, "train")
        mesh = make_host_mesh(data=args.agents)
        INPUT_SHAPES[shape.name] = shape
        shape_name = shape.name
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        shape_name = args.shape
        shape = INPUT_SHAPES[shape_name]

    with mesh:
        bundle = S.build_train(cfg, mesh, shape_name,
                               combine_override=args.combine)
        print(f"[train] {cfg.name}: K={bundle.K} agents, "
              f"T={bundle.T} tasks × {bundle.tb} examples, mode={cfg.meta_mode}")
        state = bundle.init_state(seed=0)
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            state = restore_checkpoint(args.ckpt_dir, state)
            print(f"[train] restored step {int(state.step)}")
        step_fn = jax.jit(bundle.step_fn, donate_argnums=(0,))
        source = make_train_source(cfg, shape, bundle.K, bundle.T, bundle.tb)
        print(f"[train] task source: {source.n_train_domains} domains, "
              f"{source.heterogeneity} over K={bundle.K} agents, "
              f"prefetch depth {args.prefetch}")
        t0 = time.time()
        with bundle.make_pipeline(source, depth=args.prefetch,
                                  start_step=int(state.step)) as pipe:
            for i in range(args.steps):
                state, metrics = step_fn(state, next(pipe))
                if i % args.log_every == 0:
                    print(f"step {int(state.step):5d} "
                          f"loss {float(metrics['loss']):.4f} "
                          f"disagreement {float(metrics['disagreement']):.3e} "
                          f"({time.time() - t0:.1f}s)")
                if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                    save_checkpoint(args.ckpt_dir, int(state.step), state)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, int(state.step), state)
    print("[train] done")


if __name__ == "__main__":
    main()
