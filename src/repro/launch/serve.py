"""Batched serving driver: adapt-then-serve.

Dif-MAML's product is a *launch model*: at serving time an agent adapts it
to the live task with a few gradient steps (here: on a small support set),
then serves batched decode requests from the adapted model.  This driver
demonstrates the full path on CPU with a reduced config; the same
``build_serve`` bundle lowers for the production mesh in the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \\
      --batch 4 --prompt-len 8 --gen 16 --adapt-steps 2
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.data.lm_tasks import LMTaskSampler
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as S
from repro.models.transformer import build_model


def adapt(model, params, support, lr: float, steps: int):
    """Task adaptation of the launch model (inner loop at serving time)."""
    for _ in range(steps):
        g = jax.grad(model.loss_fn)(params, support)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--adapt-steps", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()
    dt = S.DTYPES[cfg.dtype] if not args.reduced else jnp.float32

    with mesh:
        params = model.init(jax.random.key(0), dt)
        sampler = LMTaskSampler(cfg.padded_vocab, args.prompt_len + args.gen)
        support = sampler.sample_task(0, args.batch, seed=1)
        support = {k: jnp.asarray(v) for k, v in support.items()}
        if cfg.arch_type == "audio":
            support["encoder_frames"] = jnp.zeros(
                (args.batch, cfg.encoder_frames, cfg.d_model), dt)
        if cfg.arch_type == "vlm":
            support["image_patches"] = jnp.zeros(
                (args.batch, cfg.num_patches, cfg.d_model), dt)
        t0 = time.time()
        params = adapt(model, params, support, cfg.inner_lr, args.adapt_steps)
        print(f"[serve] adapted launch model in {time.time()-t0:.2f}s "
              f"({args.adapt_steps} steps)")

        B = args.batch
        total = args.prompt_len + args.gen
        enc = None
        if cfg.arch_type == "audio":
            enc = model.encode(params, support["encoder_frames"])
        elif cfg.arch_type == "vlm":
            enc = support["image_patches"] @ params["vision_proj"]
        cache = model.init_cache(B, total, dt, params=params, enc=enc)
        step = jax.jit(model.decode_step)

        prompt = np.asarray(support["tokens"])[:, : args.prompt_len]
        out_tokens = [prompt[:, i] for i in range(args.prompt_len)]
        tok = jnp.asarray(prompt[:, :1])
        t0 = time.time()
        for t in range(total - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.full((B,), t, jnp.int32))
            if t + 1 < args.prompt_len:           # teacher-force the prompt
                tok = jnp.asarray(prompt[:, t + 1: t + 2])
            else:
                if args.temperature > 0:
                    key = jax.random.fold_in(jax.random.key(7), t)
                    nxt = jax.random.categorical(
                        key, logits[:, 0] / args.temperature, axis=-1)
                else:
                    nxt = jnp.argmax(logits[:, 0], axis=-1)
                tok = nxt[:, None].astype(jnp.int32)
                out_tokens.append(np.asarray(tok)[:, 0])
        dt_s = time.time() - t0
        gen = np.stack(out_tokens, axis=1)
        print(f"[serve] {B} seqs × {total} steps in {dt_s:.2f}s "
              f"({B * args.gen / dt_s:.1f} tok/s)")
        print("[serve] sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
