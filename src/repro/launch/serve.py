"""Batched serving driver: adapt-then-serve on the shared adaptation engine.

Dif-MAML's product is a *launch model*: at serving time an agent adapts it
to the live task with a few gradient steps, then serves batched decode
requests from the adapted model.  Adaptation here is
``maml.inner_adapt`` — the exact code path the meta step differentiates
through (freeze masks, remat, multi-step scan all track automatically) —
applied to the **centroid** of a training checkpoint (restore → mean over
the agent axis) on an ``eval_sample`` support episode from the unified
``TaskSource`` surface; decode then runs through the ``ServeBundle``.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \\
      --batch 4 --prompt-len 8 --gen 16 --adapt-steps 2 --seed 0 \\
      [--ckpt-dir ckpts/seed0]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_centroid
from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import InputShape
from repro.core import maml
from repro.data.lm_tasks import LMTaskSource
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as S
from repro.models.transformer import build_model


def make_support_source(cfg, seq_len: int, task_batch: int,
                        seed: int = 0) -> LMTaskSource:
    """Serve-time episode stream: one live task per request, drawn from a
    small domain universe whose tail is held out — ``split='unseen'``
    reproduces the launch scenario (adapt to a domain never trained on)."""
    return LMTaskSource(
        vocab_size=cfg.padded_vocab, seq_len=seq_len, K=1,
        tasks_per_agent=1, task_batch=task_batch,
        n_domains=8, holdout_domains=2, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--adapt-steps", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="drives launch-model init (no checkpoint), the "
                         "support episode draw, and sampling — serve-time "
                         "sampling is reproducible per seed, not fixed")
    ap.add_argument("--ckpt-dir", default=None,
                    help="training checkpoint dir (e.g. ckpts/seed0): the "
                         "launch model is the checkpoint's agent-centroid; "
                         "omit to serve from a fresh init")
    ap.add_argument("--split", default=None,
                    choices=["recurring", "unseen", "full"],
                    help="which eval split the live task is drawn from "
                         "(default: unseen — the launch scenario)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh()
    dt = S.DTYPES[cfg.dtype] if not args.reduced else jnp.float32

    B = args.batch
    total = args.prompt_len + args.gen
    INPUT_SHAPES["serve_adapt"] = InputShape("serve_adapt", total, B, "decode")

    with mesh:
        bundle = S.build_serve(cfg, mesh, "serve_adapt")
        if args.ckpt_dir:
            params = restore_centroid(args.ckpt_dir, bundle.params_specs)
            print(f"[serve] launch model = checkpoint centroid "
                  f"({args.ckpt_dir})")
        else:
            params = model.init(jax.random.key(args.seed), dt)
            print(f"[serve] launch model = fresh init (seed {args.seed})")

        # -- adapt: one eval episode from the TaskSource surface ------------
        source = make_support_source(cfg, total, B, seed=args.seed)
        ep = source.eval_sample(1, split=args.split)
        take0 = lambda tree: {k: jnp.asarray(v[0]) for k, v in tree.items()}
        support = take0(ep.support)
        support.update(S.modality_extras(cfg, (B,), dt))

        adapt_fn = jax.jit(lambda p, batch: maml.inner_adapt(
            model.loss_fn, p, batch, alpha=cfg.inner_lr,
            steps=args.adapt_steps, first_order=True))
        t0 = time.time()
        params = jax.block_until_ready(adapt_fn(params, support))
        print(f"[serve] adapted launch model to domain "
              f"{int(np.asarray(ep.domains)[0])} in {time.time()-t0:.2f}s "
              f"({args.adapt_steps} steps via maml.inner_adapt)")

        # -- serve: batched decode through the ServeBundle ------------------
        enc = None
        if cfg.arch_type == "audio":
            enc = model.encode(params, support["encoder_frames"])
        elif cfg.arch_type == "vlm":
            enc = support["image_patches"] @ params["vision_proj"]
        cache = model.init_cache(B, total, dt, params=params, enc=enc)
        step = jax.jit(bundle.step_fn)

        # decode prompts come from the episode's *query* half: fresh
        # sequences of the same domain the model just adapted to
        prompt = np.asarray(ep.query["tokens"][0])[:, : args.prompt_len]
        out_tokens = [prompt[:, i] for i in range(args.prompt_len)]
        tok = jnp.asarray(prompt[:, :1])
        sample_key = jax.random.key(args.seed)
        t0 = time.time()
        for t in range(total - 1):
            logits, cache = step(params, cache, tok,
                                 jnp.full((B,), t, jnp.int32))
            if t + 1 < args.prompt_len:           # teacher-force the prompt
                tok = jnp.asarray(prompt[:, t + 1: t + 2])
            else:
                if args.temperature > 0:
                    key = jax.random.fold_in(sample_key, t)
                    nxt = jax.random.categorical(
                        key, logits[:, 0] / args.temperature, axis=-1)
                else:
                    nxt = jnp.argmax(logits[:, 0], axis=-1)
                tok = nxt[:, None].astype(jnp.int32)
                out_tokens.append(np.asarray(tok)[:, 0])
        dt_s = time.time() - t0
        gen = np.stack(out_tokens, axis=1)
        print(f"[serve] {B} seqs × {total} steps in {dt_s:.2f}s "
              f"({B * args.gen / dt_s:.1f} tok/s)")
        print("[serve] sample:", gen[0].tolist())


if __name__ == "__main__":
    main()
