"""Serving CLI: adaptation-as-a-service over a launch-model checkpoint.

Dif-MAML's product is a *launch model*: at serving time an agent adapts it
to each live task with a few gradient steps, then serves batched decode
requests from the adapted model.  The machinery lives in
``repro.serve.ServeEngine`` — batched (vmapped, bucket-compiled)
``inner_adapt`` over concurrent user episodes, an LRU adapted-state cache
keyed by task signature (recurring users skip re-adaptation via low-rank
delta reconstruction), and a dispatch-free two-scan decode.  This module
is the thin CLI: restore the checkpoint centroid (or a fresh init), drive
``--users`` concurrent requests for ``--rounds`` rounds (round 2+ re-draws
the same tasks — the recurring-user fast path), decode from the first
adapted model, and optionally write the engine's ``kind=serve`` record to
a JSONL run log.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \\
      --batch 4 --prompt-len 8 --gen 16 --adapt-steps 2 --seed 0 \\
      [--users 4 --rounds 2] [--ckpt-dir ckpts/seed0] [--run-log serve.jsonl]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_centroid
from repro.configs import get_config
from repro.data.lm_tasks import LMTaskSource
from repro.launch import steps as S
from repro.serve import ServeEngine


def make_support_source(cfg, seq_len: int, task_batch: int,
                        seed: int = 0) -> LMTaskSource:
    """Serve-time episode stream: one live task per request, drawn from a
    small domain universe whose tail is held out — ``split='unseen'``
    reproduces the launch scenario (adapt to a domain never trained on)."""
    return LMTaskSource(
        vocab_size=cfg.padded_vocab, seq_len=seq_len, K=1,
        tasks_per_agent=1, task_batch=task_batch,
        n_domains=8, holdout_domains=2, seed=seed)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--adapt-steps", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="drives launch-model init (no checkpoint), the "
                         "support episode draws, and sampling — serve-time "
                         "sampling is reproducible per seed, not fixed")
    ap.add_argument("--ckpt-dir", default=None,
                    help="training checkpoint dir (e.g. ckpts/seed0): the "
                         "launch model is the checkpoint's agent-centroid; "
                         "omit to serve from a fresh init")
    ap.add_argument("--split", default=None,
                    choices=["recurring", "unseen", "full"],
                    help="which eval split the live tasks are drawn from "
                         "(default: unseen — the launch scenario)")
    ap.add_argument("--users", type=int, default=4,
                    help="concurrent adaptation requests per round (one "
                         "vmapped inner_adapt dispatch, bucket-padded)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="request rounds; rounds after the first re-draw "
                         "the same tasks, exercising the adapted-state "
                         "cache's recurring-user fast path")
    ap.add_argument("--cache-capacity", type=int, default=64)
    ap.add_argument("--rank", type=int, default=8,
                    help="low-rank delta factorization rank (per matrix "
                         "leaf, fidelity-gated — see serve/lowrank.py)")
    ap.add_argument("--run-log", default=None,
                    help="JSONL path for the engine's kind=serve record "
                         "(cache counters, adapt p50/p99, per-phase tok/s)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dt = S.DTYPES[cfg.dtype] if not args.reduced else jnp.float32

    B, total = args.batch, args.prompt_len + args.gen
    engine = ServeEngine(
        cfg, prompt_len=args.prompt_len, gen=args.gen, batch=B,
        adapt_steps=args.adapt_steps, temperature=args.temperature,
        cache_capacity=args.cache_capacity, rank=args.rank, dtype=dt)

    if args.ckpt_dir:
        params = restore_centroid(args.ckpt_dir, engine.bundle.params_specs)
        print(f"[serve] launch model = checkpoint centroid ({args.ckpt_dir})")
    else:
        params = engine.model.init(jax.random.key(args.seed), dt)
        print(f"[serve] launch model = fresh init (seed {args.seed})")
    engine.load_params(params)

    # -- adapt: --users concurrent episodes per round; same tasks each
    # round (same eval seed → same domain draw), so rounds 2+ are the
    # recurring-user path and resolve from the adapted-state cache
    source = make_support_source(cfg, total, B, seed=args.seed)
    ep = None
    for rnd in range(args.rounds):
        ep = source.eval_sample(args.users, seed=args.seed, split=args.split)
        requests = engine.requests_from_episode(source, ep)
        adapted, m = engine.adapt(requests)
        doms = np.asarray(ep.domains).tolist()
        print(f"[serve] round {rnd}: adapted {m['n']} users "
              f"(domains {doms}) in {m['seconds']:.3f}s — "
              f"{m['hits']} cache hits, {m['misses']} misses "
              f"(buckets {m['buckets']})")

    # -- decode from the first user's adapted model: prompts are fresh
    # sequences of the domain it just adapted to (the episode's query half)
    prompt = np.asarray(ep.query["tokens"][0])[:, : args.prompt_len]
    tokens, dm = engine.decode(adapted[0], prompt, seed=args.seed)
    print(f"[serve] prompt: {B} seqs × {args.prompt_len} tok in "
          f"{dm['prefill_s']:.3f}s ({dm['prompt_tok_s']:.1f} tok/s prefill)")
    print(f"[serve] decode: {B} seqs × {args.gen} tok in "
          f"{dm['decode_s']:.3f}s ({dm['decode_tok_s']:.1f} tok/s)")
    print("[serve] sample:", tokens[0].tolist())

    stats = engine.cache.stats()
    print(f"[serve] cache: {stats['hits']} hits / {stats['misses']} misses "
          f"/ {stats['evictions']} evictions, {stats['residents']} "
          f"residents, {stats['compression']:.2f}x delta compression")

    if args.run_log:
        from repro.launch.train import RunLog
        log = RunLog(args.run_log)
        log.write(**engine.log_record())
        log.close()
        print(f"[serve] run log -> {args.run_log}")


if __name__ == "__main__":
    main()
