"""Builders: per-(architecture × input-shape × mesh) train/serve steps with
full sharding trees and ShapeDtypeStruct input specs — shared by the
dry-run, the trainer, and the server.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape, resolve_input_shape
from repro.core import (MetaConfig, TopologyConfig, UpdateConfig, diffusion,
                        update)
from repro.core.meta_trainer import (TrainState, make_meta_step, schedule_for,
                                     strategy_for_combine)
from repro.models.init import Spec, abstract, axes_tree, with_agent_axis
from repro.models.transformer import build_model
from repro.optim import get_optimizer
from repro.sharding.rules import rules_for, spec_for, tree_shardings

PyTree = Any

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


# ---------------------------------------------------------------------------
# Agent / batch geometry
# ---------------------------------------------------------------------------

def agent_count(cfg: ArchConfig, mesh: Mesh) -> int:
    """K for an arch on a mesh.  A first-class ``agent`` mesh axis defines
    K outright; legacy meshes fall back to ``cfg.placement`` (one agent per
    pod, or agents tiling the full data-parallel extent)."""
    from repro.sharding.rules import _axis_sizes
    sizes = _axis_sizes(mesh)
    if "agent" in sizes:
        return sizes["agent"]
    if cfg.placement == "pod":
        return sizes.get("pod", 1)
    return sizes.get("data", 1) * sizes.get("pod", 1)


def batch_geometry(cfg: ArchConfig, shape: InputShape, K: int
                   ) -> tuple[int, int]:
    """(tasks_per_agent, task_batch): B = K · T · tb · 2 (support+query).

    T starts at ``cfg.meta_tasks`` and falls back toward 1 until it divides
    the per-agent half-batch; the global batch itself must factor exactly —
    a remainder would silently vanish in the (K, T, 2·tb) fold."""
    B = shape.global_batch
    if K < 1 or B < 2 * K or B % (2 * K):
        raise ValueError(
            f"global_batch={B} cannot be split across K={K} agents: the "
            f"meta step folds the batch as B = K·T·tb·2 (support+query), "
            f"so global_batch must be a multiple of 2·K = {2 * max(K, 1)} "
            f"(minimum {2 * max(K, 1)})")
    half = B // K // 2
    T = cfg.meta_tasks
    while half % T:
        T -= 1
    if T != cfg.meta_tasks:
        import warnings
        warnings.warn(
            f"meta_tasks={cfg.meta_tasks} does not divide the per-agent "
            f"half-batch {half} (global_batch={B}, K={K}); falling back to "
            f"T={T} tasks per agent — the eq. 4 multi-task average degrades "
            f"(T=1 erases it entirely). Pick a global_batch divisible by "
            f"2·K·meta_tasks to keep the requested T.",
            RuntimeWarning, stacklevel=2)
    return T, half // T


def modality_extras(cfg: ArchConfig, lead: tuple[int, ...], dt) -> dict:
    """Zero-stub modality inputs (audio frames / vision patches) the model's
    loss expects beyond tokens/labels, with the given leading axes — the ONE
    place the modality-input contract is spelled; train pipeline
    (``lead=(B,)``), eval harness (``lead=(n_tasks, tb)``) and serve all
    build their stubs here."""
    extras = {}
    if cfg.arch_type == "audio":
        extras["encoder_frames"] = jnp.zeros(
            lead + (cfg.encoder_frames, cfg.d_model), dt)
    if cfg.arch_type == "vlm":
        extras["image_patches"] = jnp.zeros(
            lead + (cfg.num_patches, cfg.d_model), dt)
    return extras


def split_meta_batch(cfg: ArchConfig, batch: dict, K: int, T: int, tb: int,
                     fold_spec: P | None = None, mesh: Mesh | None = None
                     ) -> tuple[dict, dict]:
    """(B, ...) arrays → support/query dicts with leading (K, T, tb, ...).

    ``fold_spec`` re-asserts the sharding of the folded layout — XLA's
    sharding propagation cannot split a dim-0 sharding across the
    non-adjacent (agent, task-batch) factors of the reshape, and silently
    replicates the batch without this constraint (measured: ~16× per-device
    FLOPs on pod-placement archs)."""

    def leaf(x):
        rest = x.shape[1:]
        out = x.reshape((K, T, 2 * tb) + rest)
        if fold_spec is not None and mesh is not None:
            spec = P(*(tuple(fold_spec) + (None,) * len(rest)))
            out = jax.lax.with_sharding_constraint(
                out, NamedSharding(mesh, spec))
        return out

    folded = {k: leaf(v) for k, v in batch.items()}
    support = {k: v[:, :, :tb] for k, v in folded.items()}
    query = {k: v[:, :, tb:] for k, v in folded.items()}
    return support, query


# ---------------------------------------------------------------------------
# Input specs (deliverable f): ShapeDtypeStructs for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape_name: str | InputShape
                ) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × input-shape).  The shape
    may be a registry name or a bare :class:`InputShape` (one-shot
    geometries need not touch the global registry).

    train/prefill: {tokens, labels [, encoder_frames | image_patches]}
    decode:        {token, pos, cache}
    """
    shape = resolve_input_shape(shape_name)
    dt = DTYPES[cfg.dtype]
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.arch_type == "audio":
            specs["encoder_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_frames, cfg.d_model), dt)
        if cfg.arch_type == "vlm":
            specs["image_patches"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), dt)
        return specs
    # decode: one new token against a seq_len cache
    model = build_model(cfg)
    cache = abstract(model.cache_specs(B, S), dt)
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        "cache": cache,
    }


def input_axes(cfg: ArchConfig, shape_name: str | InputShape
               ) -> dict[str, Any]:
    """Logical axes matching input_specs (for sharding assignment)."""
    shape = resolve_input_shape(shape_name)
    if shape.kind in ("train", "prefill"):
        axes: dict[str, Any] = {
            "tokens": ("batch", None),
            "labels": ("batch", None),
        }
        if cfg.arch_type == "audio":
            axes["encoder_frames"] = ("batch", None, "embed")
        if cfg.arch_type == "vlm":
            axes["image_patches"] = ("batch", None, "embed")
        return axes
    model = build_model(cfg)
    cache_axes = axes_tree(model.cache_specs(shape.global_batch, shape.seq_len))
    return {"token": ("batch", None), "pos": ("batch",), "cache": cache_axes}


# ---------------------------------------------------------------------------
# Train step (Dif-MAML meta-iteration)
# ---------------------------------------------------------------------------

def meta_config_for(cfg: ArchConfig, K: int, T: int, *,
                    strategy: str | None = None,
                    schedule: str = "static",
                    link_failure_p: float = 0.2,
                    schedule_seed: int = 0) -> MetaConfig:
    """Assemble the nested MetaConfig from the arch's meta fields plus the
    run's strategy/schedule choices (``--strategy``/``--topology-schedule``
    in launch/train.py)."""
    if K == 1:
        strategy, backend = "none", "none"
    else:
        strategy, backend = strategy or "atc", cfg.combine
    return MetaConfig(
        num_agents=K,
        tasks_per_agent=T,
        inner_lr=cfg.inner_lr,
        inner_steps=cfg.inner_steps,
        outer_optimizer=cfg.outer_optimizer,
        outer_lr=cfg.outer_lr,
        hvp_subsample=cfg.hvp_subsample,
        update_config=UpdateConfig(strategy=strategy, inner=cfg.meta_mode,
                                   backend=backend),
        topology_config=TopologyConfig(graph=cfg.topology,
                                       schedule=schedule,
                                       link_failure_p=link_failure_p,
                                       seed=schedule_seed),
    )


@dataclasses.dataclass
class TrainBundle:
    cfg: ArchConfig
    mesh: Mesh
    K: int
    T: int
    tb: int
    step_fn: Any                  # (state, batch) -> (state, metrics)
    state_specs: Any              # abstract TrainState
    state_shardings: Any
    batch_shardings: Any
    init_state: Any               # () -> TrainState (materialized)
    loss_fn: Any = None           # (params, batch) -> scalar (single agent)
    mcfg: Any = None              # the assembled MetaConfig
    schedule: Any = None          # TopologySchedule (None when K == 1)
    outer_dtype: str = ""         # resolved params/grads storage dtype
    combine_dtype: str = ""       # resolved combine wire format
    combine_backend: str = ""     # resolved combine backend ('auto' applied)

    def make_eval_harness(self, inner_steps: int | None = None):
        """The in-training recurring-vs-unseen eval engine, bound to this
        bundle's model loss and inner learning rate — the same
        ``maml.inner_adapt`` path the meta step differentiates through."""
        from repro.eval.harness import EvalHarness
        return EvalHarness(
            self.loss_fn, inner_lr=self.cfg.inner_lr,
            inner_steps=self.cfg.inner_steps if inner_steps is None
            else inner_steps)

    def eval_prepare(self):
        """``prepare`` hook for :meth:`EvalHarness.evaluate`: appends the
        per-task modality stubs (``modality_extras``) the model's loss
        expects, on the task-leading eval layout."""
        cfg, dt = self.cfg, DTYPES[self.cfg.dtype]

        def add(d):
            extras = modality_extras(cfg, d["tokens"].shape[:2], dt)
            return {**d, **extras} if extras else d

        return lambda sq: (add(sq[0]), add(sq[1]))

    def make_pipeline(self, source, *, depth: int = 2, start_step: int = 0,
                      stack: int | None = None):
        """Wrap a ``TaskSource`` bound to this bundle's (K, T, tb) geometry
        in a :class:`~repro.data.pipeline.MetaBatchPipeline` yielding
        device-ready global batches: the episode is flattened to the
        ``(B, ...)`` layout ``step_fn`` folds back with
        ``split_meta_batch``, modality stubs are appended, and the batch is
        ``device_put`` onto ``batch_shardings`` on the prefetch thread —
        host-side sampling and H2D overlap the jitted step.

        ``stack=C`` feeds the superstep driver: each ``next()`` yields C
        consecutive meta-batches stacked on a new leading dispatch axis of
        size C (one host assembly + one ``device_put`` per dispatch), the
        layout :func:`make_superstep`'s ``lax.scan`` unstacks on device —
        C=1 still carries the (1, B, ...) axis so one driver serves every
        C.  ``stack=None`` (default) keeps the legacy per-step ``(B, ...)``
        layout for direct ``step_fn`` consumers.  The sample sequence is
        identical either way."""
        from repro.data.pipeline import MetaBatchPipeline
        src_tb = getattr(source, "task_batch", self.tb)
        if (source.K, source.tasks_per_agent, src_tb) != (self.K, self.T,
                                                          self.tb):
            raise ValueError(
                f"source geometry (K={source.K}, T={source.tasks_per_agent}, "
                f"tb={src_tb}) does not match the bundle's (K={self.K}, "
                f"T={self.T}, tb={self.tb})")
        cfg, dt = self.cfg, DTYPES[self.cfg.dtype]
        B = self.K * self.T * self.tb * 2

        if stack is None:
            extras = modality_extras(cfg, (B,), dt)

            def prepare(ep):
                batch = ep.as_flat_batch()
                batch.update(extras)
                return jax.device_put(
                    batch, {k: self.batch_shardings[k] for k in batch})
        else:
            if stack < 1:
                raise ValueError(f"stack must be >= 1, got {stack}")
            extras = modality_extras(cfg, (stack, B), dt)
            # the stacked leading (dispatch) axis is unsharded; every batch
            # dim keeps its per-step spec one position to the right
            stacked_sh = {
                k: NamedSharding(self.mesh, P(*((None,) + tuple(sh.spec))))
                for k, sh in self.batch_shardings.items()}

            def prepare(eps):
                eps = eps if isinstance(eps, list) else [eps]
                flat = [ep.as_flat_batch() for ep in eps]
                batch = {k: np.stack([b[k] for b in flat]) for k in flat[0]}
                batch.update(extras)
                return jax.device_put(
                    batch, {k: stacked_sh[k] for k in batch})

        return MetaBatchPipeline(source, depth=depth, prepare=prepare,
                                 start_step=start_step,
                                 stack=1 if stack is None else stack)

    def lint_metadata(self) -> dict:
        """The facts the compiled-program lint rules (``repro.analysis``)
        need about this bundle's train step: mesh geometry, the combine's
        schedule degree and per-device wire-shard size, backend wire
        metadata, and the donated-leaf count — derived here, in the one
        place that owns the bundle's sharding and combine resolution."""
        from repro.compat import mesh_axis_sizes
        from repro.launch.hlo_cost import tree_shard_bytes
        sizes = mesh_axis_sizes(self.mesh)
        deg = self.schedule.ir().degree if self.schedule is not None else 0
        shard = tree_shard_bytes(
            self.state_shardings.params, self.state_specs.params, sizes,
            elem_bytes=diffusion.wire_elem_bytes(self.combine_dtype))
        backend = self.combine_backend or "none"
        try:
            bmeta = diffusion.backend_lint_metadata(backend,
                                                    self.combine_dtype)
        except ValueError:
            bmeta = {"backend": backend, "emits_permutes": False,
                     "wire_hlo_dtype": "f32"}
        ucfg = self.mcfg.update_config if self.mcfg is not None else None
        return {
            "n_dev": int(np.prod(self.mesh.devices.shape)),
            "mesh_axes": dict(sizes),
            "K": self.K,
            "degree": int(deg),
            "shard_bytes": int(shard),
            "wire_dtype": self.combine_dtype,
            "combine_every": int(getattr(ucfg, "combine_every", 1) or 1),
            "expected_aliases": len(jax.tree.leaves(self.state_specs)),
            **bmeta,
        }


def opt_state_axes(opt_name: str, params_axes: PyTree) -> PyTree:
    from repro.optim.optimizers import AdamState, MomentumState
    if opt_name in ("adam", "adamw"):
        return AdamState((), params_axes, params_axes)
    if opt_name == "momentum":
        return MomentumState(params_axes)
    return ()


def build_train(cfg: ArchConfig, mesh: Mesh,
                shape_name: str | InputShape = "train_4k",
                combine_override: str | None = None, *,
                strategy: str | None = None,
                schedule: str = "static",
                link_failure_p: float = 0.2,
                schedule_seed: int = 0) -> TrainBundle:
    shape = resolve_input_shape(shape_name)
    assert shape.kind in ("train", "prefill")
    dt = DTYPES[cfg.dtype]
    # Outer-loop storage: params/grads live in out_dt; Adam moments stay
    # fp32 regardless (adam.init allocates f32, updates come back in
    # p.dtype).  Activations/inputs keep cfg.dtype.
    outer_dtype = cfg.outer_dtype or cfg.dtype
    out_dt = DTYPES[outer_dtype]
    wire_dtype = diffusion.resolve_combine_dtype(outer_dtype,
                                                 cfg.combine_dtype or None)
    model = build_model(cfg)
    agent_mesh = "agent" in mesh.axis_names
    intra_agent_data = "data" in mesh.axis_names and (
        agent_mesh or cfg.placement == "pod")
    if intra_agent_data:
        # keep per-task activations batch-sharded over the data axis (the
        # agent/task dims are vmapped away above this constraint)
        model.act_sharding = NamedSharding(mesh, P("data", None, None))
    K = agent_count(cfg, mesh)
    T, tb = batch_geometry(cfg, shape, K)
    mcfg = meta_config_for(cfg, K, T, strategy=strategy, schedule=schedule,
                           link_failure_p=link_failure_p,
                           schedule_seed=schedule_seed)
    if combine_override:
        # a bare 'none'/'centralized' override keeps the legacy meaning of
        # selecting that *strategy* (unless one was requested explicitly)
        uc = mcfg.update_config
        strat = (uc.strategy if strategy
                 else strategy_for_combine(combine_override,
                                           default=uc.strategy))
        mcfg = dataclasses.replace(mcfg, update_config=dataclasses.replace(
            uc, strategy=strat, backend=combine_override))
    opt = get_optimizer(cfg.outer_optimizer, cfg.outer_lr)
    sched = schedule_for(mcfg) if K > 1 else None
    A = sched.stacked() if sched is not None else np.ones((1, 1))

    # ---- shardings (needed below for the sparse combine's in_specs) -------
    rules = rules_for(cfg, mesh, kind="train")
    p_specs = with_agent_axis(model.specs(), K)
    p_axes = axes_tree(p_specs)
    p_abs = abstract(p_specs, out_dt)
    params_sh = tree_shardings(p_axes, p_abs, rules, mesh)

    multi_pod = "pod" in mesh.axis_names
    if agent_mesh:
        agent_axis = "agent"
    elif cfg.placement == "pod" and multi_pod:
        agent_axis = "pod"
    else:
        agent_axis = "data"
    strat_obj = update.get_strategy(
        mcfg.update_config.strategy if K > 1 else "none")
    backend = mcfg.update_config.backend
    if backend == "sparse":
        # Sparse neighbor combine.  On an agent-axis mesh the shard_map
        # form is always valid (extent == K by construction) and gets the
        # real leaf specs below.  On legacy meshes: weighted rolls over the
        # agent-sharded dim — under GSPMD each roll lowers to collective-
        # permutes of one shard per circular offset, while every other (TP)
        # dim keeps its sharding; a partial-manual shard_map whose in_specs
        # omit the auto axes would instead all-gather TP shards at entry
        # (measured +77% wire).
        backend = "mesh_sparse" if agent_mesh else "sparse_host"
    # Stacked (dynamic) schedules: static sparse backends upgrade to their
    # *_dynamic siblings (same permute rounds, step-gathered weights)
    backend = diffusion.resolve_schedule_backend(backend, A)
    # The name the lint layer sees must be the backend actually lowered —
    # resolve 'auto' the same way make_combine will, and record 'none'
    # when no combine is injected at all (K=1 / strategies without one).
    if backend == "auto":
        resolved_backend = diffusion.select_backend(A, mesh=mesh,
                                                    axis_name=agent_axis)
    else:
        resolved_backend = backend
    combine_fn = None
    if backend == "fused":
        # One-pass combine-then-update: make_meta_step builds the fused
        # outer from mcfg (it owns optimizer/strategy/comm wiring); no
        # combine_fn is injected — the replicated (K, m) kernel layout has
        # no shard_map exchange, so a first-class agent mesh must keep the
        # ppermute backends.
        if agent_mesh:
            raise ValueError(
                "backend='fused' runs the packed single-host kernel layout "
                "and cannot serve a mesh with a first-class agent axis "
                f"(mesh axes {mesh.axis_names}); use 'sparse'/'mesh_sparse' "
                "there, or a host mesh for the fused outer step.")
    elif strat_obj.needs_combine_fn and K > 1:
        param_specs = jax.tree.map(lambda s: s.spec, params_sh)
        combine_fn = diffusion.make_combine(
            backend, A=A, axis_name=agent_axis, mesh=mesh,
            in_specs=param_specs, combine_dtype=wire_dtype)
    else:
        resolved_backend = "none"
    freeze_mask = None
    if cfg.inner_freeze:
        # ANIL-style: the named subtree (e.g. 'encoder') is frozen in the
        # inner loop — its inner gradient, update, and curvature cross-terms
        # vanish; the outer step still trains it (EXPERIMENTS HC3).
        freeze_mask = jax.tree_util.tree_map_with_path(
            lambda path, _: any(getattr(k, "key", None) == cfg.inner_freeze
                                for k in path),
            abstract(model.specs(), dt))
    step = make_meta_step(model.loss_fn, mcfg, optimizer=opt, A=A,
                          combine_fn=combine_fn, freeze_mask=freeze_mask)
    if agent_mesh:
        # agent dim on the agent axis; the task-batch dim rides intra-agent
        # data parallelism when the mesh has it (2D (agent, model) meshes
        # keep the per-agent batch local)
        fold_spec = (P("agent", None, "data") if intra_agent_data
                     else P("agent"))
    elif cfg.placement == "pod":
        fold_spec = P("pod" if multi_pod else None, None, "data")
    else:
        fold_spec = P(("pod", "data") if multi_pod else "data")

    def train_step(state: TrainState, batch: dict):
        support, query = split_meta_batch(cfg, batch, K, T, tb,
                                          fold_spec=fold_spec, mesh=mesh)
        return step(state, support, query)

    opt_abs = jax.eval_shape(opt.init, p_abs)
    o_axes = opt_state_axes(cfg.outer_optimizer, p_axes)
    opt_sh = tree_shardings(o_axes, opt_abs, rules, mesh) if o_axes != () else ()
    state_abs = TrainState(jax.ShapeDtypeStruct((), jnp.int32), p_abs, opt_abs)
    state_sh = TrainState(NamedSharding(mesh, P()), params_sh, opt_sh)

    in_axes_map = input_axes(cfg, shape_name)
    in_specs = input_specs(cfg, shape_name)
    batch_sh = tree_shardings(in_axes_map, in_specs, rules, mesh)

    def init_state_fn(seed: int = 0) -> TrainState:
        keys = jax.random.split(jax.random.key(seed), K)
        params = jax.vmap(lambda k: model.init(k, out_dt))(keys)
        return TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))

    return TrainBundle(cfg, mesh, K, T, tb, train_step, state_abs, state_sh,
                       batch_sh, init_state_fn, loss_fn=model.loss_fn,
                       mcfg=mcfg, schedule=sched, outer_dtype=outer_dtype,
                       combine_dtype=wire_dtype,
                       combine_backend=resolved_backend)


# ---------------------------------------------------------------------------
# Superstep: C meta-steps per dispatch (the dispatch-free training loop)
# ---------------------------------------------------------------------------

# Scalar step metrics carried out of the scan — one (C,) array per key, so a
# C-step dispatch costs ONE host fetch instead of C device syncs.  Per-agent
# metrics (K-vectors) stay inside the step; consumers that need them run at
# C=1 or via the eval harness.
SUPERSTEP_METRICS = ("loss", "disagreement")


def make_superstep(step_fn):
    """Fold ``step_fn`` into ``superstep(state, batches) -> (state, metrics)``.

    ``batches``: the pytree of one meta-batch with an extra leading
    dispatch axis of size C (``TrainBundle.make_pipeline(stack=C)``'s
    layout).  The C meta-steps run inside one ``lax.scan`` — a single
    jitted, buffer-donatable call, so the Python loop dispatches (and
    syncs metrics to host) once per C steps instead of once per step.
    ``metrics`` maps each :data:`SUPERSTEP_METRICS` key to a ``(C,)``
    device array (step-resolved, fetched in one transfer).

    Step-for-step identical to calling ``step_fn`` C times: the scan body
    IS the per-step function, and the batch sequence is the same because
    the stacked pipeline groups — never reorders — episodes.
    """

    def superstep(state, batches):
        def body(st, batch):
            st, metrics = step_fn(st, batch)
            return st, {k: metrics[k] for k in SUPERSTEP_METRICS}

        return jax.lax.scan(body, state, batches)

    return superstep


# ---------------------------------------------------------------------------
# Prefill step (inference-prefill: full-sequence forward)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PrefillBundle:
    cfg: ArchConfig
    mesh: Mesh
    step_fn: Any                  # (params, batch) -> logits
    params_specs: Any
    params_shardings: Any
    batch_shardings: Any


def build_prefill(cfg: ArchConfig, mesh: Mesh, shape_name: str | InputShape
                  ) -> PrefillBundle:
    """Inference prefill: one full-sequence forward of the launch model
    (no agent axis, no meta step) producing next-token logits."""
    dt = DTYPES[cfg.dtype]
    # inference uses the GShard one-hot MoE dispatch where the dispatch/
    # expert flop ratio allows (−75% FLOPs/dev, −91% wire on jamba/mixtral
    # prefill; 'auto' keeps sort/gather for high-k small-f MoEs like
    # DeepSeek where the one-hot einsum would exceed the expert GEMMs) —
    # EXPERIMENTS HC2
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_dispatch="auto")
    model = build_model(cfg)
    model.act_sharding = NamedSharding(mesh, P("data", None, None))

    def prefill_step(params, batch):
        return model.forward(params, batch)

    rules = rules_for(cfg, mesh, kind="decode")
    p_specs = model.specs()
    p_abs = abstract(p_specs, dt)
    params_sh = tree_shardings(axes_tree(p_specs), p_abs, rules, mesh)
    in_specs = {k: v for k, v in input_specs(cfg, shape_name).items()}
    axes = {"tokens": ("batch", None), "labels": ("batch", None)}
    if cfg.arch_type == "audio":
        axes["encoder_frames"] = ("batch", None, "embed")
    if cfg.arch_type == "vlm":
        axes["image_patches"] = ("batch", None, "embed")
    batch_sh = tree_shardings(axes, in_specs, rules, mesh)
    return PrefillBundle(cfg, mesh, prefill_step, p_abs, params_sh, batch_sh)


# ---------------------------------------------------------------------------
# Serve step (single-token decode against a KV cache)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeBundle:
    cfg: ArchConfig
    mesh: Mesh
    step_fn: Any                  # (params, cache, token, pos) -> (logits, cache)
    params_specs: Any
    params_shardings: Any
    input_shardings: Any          # dict for {token,pos,cache}


def build_serve(cfg: ArchConfig, mesh: Mesh,
                shape_name: str | InputShape) -> ServeBundle:
    shape = resolve_input_shape(shape_name)
    assert shape.kind == "decode"
    dt = DTYPES[cfg.dtype]
    model = build_model(cfg)

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    rules = rules_for(cfg, mesh, kind="decode")
    p_specs = model.specs()
    p_axes = axes_tree(p_specs)
    p_abs = abstract(p_specs, dt)
    params_sh = tree_shardings(p_axes, p_abs, rules, mesh)
    in_specs = input_specs(cfg, shape_name)
    in_axes_map = input_axes(cfg, shape_name)
    input_sh = tree_shardings(in_axes_map, in_specs, rules, mesh)
    return ServeBundle(cfg, mesh, serve_step, p_abs, params_sh, input_sh)
