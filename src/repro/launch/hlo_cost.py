"""Trip-count-aware cost model over post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once,
ignoring ``known_trip_count`` — under layer-scanned models this undercounts
FLOPs/bytes by the model depth (verified: a scanned 10× matmul reports 1×).
This module re-derives both quantities from the HLO text:

  flops  — 2 · prod(result dims) · prod(lhs contracting dims) per dot
           (+ convolutions), multiplied through the call graph with
           while-loop trip counts applied
  bytes  — per instruction: result bytes + operand bytes (via a per-
           computation symbol table), same multiplication; an
           *arithmetic-intensity* style bound on HBM traffic (upper bound:
           assumes no fusion reuse; XLA's own "bytes accessed" has the
           same convention)

Collective wire bytes keep their own parser in dryrun.py (they are not
inside scans in this codebase — the combine happens once per step).
"""
from __future__ import annotations

import re


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\":\{\"n\":\"(\d+)\"")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALL_RE = re.compile(
    r"(?:calls=|body=|condition=|branch_computations=\{|to_apply=)%?([\w\.\-]+)")


def _shape_bytes_match(m: re.Match) -> int:
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[m.group(1)]


def _shape_info(text: str):
    """All (dtype, dims) shapes in a type string; returns total bytes and
    the first shape's dims."""
    total = 0
    first_dims = None
    for m in _SHAPE_RE.finditer(text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[m.group(1)]
        if first_dims is None:
            first_dims = dims
    return total, (first_dims or [])


_COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                   "collective-permute")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


class HloCost:
    def __init__(self, hlo: str, n_dev: int = 1):
        self.comp_instrs: dict[str, list[str]] = {}
        self.n_dev = n_dev
        self._parse_computations(hlo)
        self._memo_flops: dict[str, float] = {}
        self._memo_bytes: dict[str, float] = {}
        self._memo_coll: dict[str, dict] = {}
        self.entry = self._find_entry(hlo)

    def _parse_computations(self, hlo: str):
        current = None
        for raw in hlo.splitlines():
            line = raw.rstrip()
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                current = m.group(1)
                self.comp_instrs[current] = []
                continue
            if line.strip() == "}":
                current = None
                continue
            if current is not None and "=" in line:
                self.comp_instrs[current].append(line.strip())

    def _find_entry(self, hlo: str) -> str:
        for line in hlo.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_RE.match(line.strip())
                if m:
                    return m.group(1)
        # fall back to the largest computation
        return max(self.comp_instrs, key=lambda c: len(self.comp_instrs[c]))

    # ------------------------------------------------------------------
    def _instr_tables(self, comp: str):
        """Symbol table: name -> (bytes, dims) for this computation."""
        table = {}
        for ins in self.comp_instrs.get(comp, []):
            m = _DEF_RE.match(ins)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            head = rest.split("(")[0] if "(" in rest else rest
            table[name] = _shape_info(head)
        return table

    def comp_flops(self, comp: str) -> float:
        if comp in self._memo_flops:
            return self._memo_flops[comp]
        self._memo_flops[comp] = 0.0          # cycle guard
        table = self._instr_tables(comp)
        total = 0.0
        for ins in self.comp_instrs.get(comp, []):
            m = _DEF_RE.match(ins)
            if not m:
                continue
            rest = m.group(2)
            opm = re.match(r"[^ ]+ ([\w\-]+)\(", rest)
            op = opm.group(1) if opm else ""
            if op == "dot":
                _, rdims = _shape_info(rest.split("(")[0])
                rsize = 1
                for d in rdims:
                    rsize *= d
                # contracted extent from lhs shape + contracting dims
                cd = _DIMS_RE.search(rest)
                operands = _OPND_RE.findall(rest.split("(", 1)[1])
                csize = 1
                if cd and operands and operands[0] in table:
                    lhs_dims = table[operands[0]][1]
                    for i in (int(x) for x in cd.group(1).split(",") if x):
                        if i < len(lhs_dims):
                            csize *= lhs_dims[i]
                total += 2.0 * rsize * csize
            elif op == "convolution":
                # rough: 2 * result * (kernel spatial * in_channels)
                _, rdims = _shape_info(rest.split("(")[0])
                rsize = 1
                for d in rdims:
                    rsize *= d
                operands = _OPND_RE.findall(rest.split("(", 1)[1])
                ksz = 1
                if len(operands) > 1 and operands[1] in table:
                    kd = table[operands[1]][1]
                    for d in kd[:-1]:
                        ksz *= d
                total += 2.0 * rsize * ksz
            # nested computations
            trip = 1
            tm = _TRIP_RE.search(ins)
            if tm:
                trip = int(tm.group(1))
            for callee in _CALL_RE.findall(ins):
                if callee in self.comp_instrs and callee != comp:
                    total += trip * self.comp_flops(callee)
        self._memo_flops[comp] = total
        return total

    def comp_bytes(self, comp: str) -> float:
        if comp in self._memo_bytes:
            return self._memo_bytes[comp]
        self._memo_bytes[comp] = 0.0
        table = self._instr_tables(comp)
        total = 0.0
        for ins in self.comp_instrs.get(comp, []):
            m = _DEF_RE.match(ins)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            opm = re.search(r"(?:^|\s)([a-z][\w\-]*)\(", rest)
            op = opm.group(1) if opm else ""
            trip = 1
            tm = _TRIP_RE.search(ins)
            if tm:
                trip = int(tm.group(1))
            if op in ("while", "call", "conditional"):
                # control flow: cost is the callee's, × trip count
                for callee in _CALL_RE.findall(ins):
                    if callee in self.comp_instrs and callee != comp:
                        total += trip * self.comp_bytes(callee)
                continue
            if op in ("tuple", "get-tuple-element", "parameter", "constant",
                      "bitcast", "copy", ""):
                # copies are CPU-backend aliasing artifacts; layout ops free
                continue
            operand_bytes = []
            if "(" in rest:
                args = rest[rest.index("(") + 1:].split(")")[0]
                operand_bytes = [table.get(o, (0, []))[0]
                                 for o in _OPND_RE.findall(args)]
            wbytes = table.get(name, (0, []))[0]
            if op == "dynamic-update-slice" or "dynamic-update-slice" in rest:
                # in-place window write into an aliased buffer: traffic =
                # the update window (≈ everything except the buffer itself),
                # read + written — NOT the whole buffer
                upd = sum(operand_bytes) - (max(operand_bytes) if operand_bytes else 0)
                total += 2 * upd
                continue
            if op in ("dynamic-slice", "slice") or "dynamic-slice" in rest:
                total += 2 * wbytes                        # read + write window
                continue
            # fusion (and plain ops): HBM traffic = own I/O only; fused
            # internals live in registers/VMEM.  Windowed-access heuristic:
            # an operand ≫ the result inside a loop body is a slice-read of a
            # loop-carried stack — charge the window, not the stack.
            rbytes = sum(min(b, wbytes) if (wbytes and b > 8 * wbytes) else b
                         for b in operand_bytes)
            total += wbytes + rbytes
        self._memo_bytes[comp] = total
        return total

    # ------------------------------------------------------------------
    def _group_size(self, ls: str) -> int:
        m = _GROUPS_IOTA_RE.search(ls)
        if m:                           # [n_groups, group_size]<=[...]
            return max(1, int(m.group(2)))
        m = _GROUPS_LIST_RE.search(ls)
        if m:
            return max(1, len(m.group(1).split(",")))
        return self.n_dev

    def comp_collectives(self, comp: str) -> dict:
        """Per-device wire bytes by collective op, trip counts applied.
        Wire model (ring algorithms, group size K):
          all-gather / all-to-all   result · (K−1)/K
          reduce-scatter            result · (K−1)
          all-reduce                result · 2(K−1)/K
          collective-permute        result
        """
        if comp in self._memo_coll:
            return self._memo_coll[comp]
        self._memo_coll[comp] = {}
        acc: dict[str, dict] = {}

        def add(op, wire, result, dtype):
            d = acc.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0,
                                    "by_dtype": {}})
            d["count"] += 1
            d["bytes"] += result
            d["wire_bytes"] += wire
            d["by_dtype"][dtype] = d["by_dtype"].get(dtype, 0) + wire

        def merge(sub: dict, trip: int):
            for op, d in sub.items():
                a = acc.setdefault(op, {"count": 0, "bytes": 0,
                                        "wire_bytes": 0, "by_dtype": {}})
                a["count"] += trip * d["count"]
                a["bytes"] += trip * d["bytes"]
                a["wire_bytes"] += trip * d["wire_bytes"]
                for dt, w in d.get("by_dtype", {}).items():
                    a["by_dtype"][dt] = a["by_dtype"].get(dt, 0) + trip * w

        for ins in self.comp_instrs.get(comp, []):
            m = _DEF_RE.match(ins)
            if not m:
                continue
            rest = m.group(2)
            opm = re.search(r"(?:^|\s)([a-z][\w\-]*?)(?:-start)?\(", rest)
            op = opm.group(1) if opm else ""
            trip = 1
            tm = _TRIP_RE.search(ins)
            if tm:
                trip = int(tm.group(1))
            for callee in _CALL_RE.findall(ins):
                if callee in self.comp_instrs and callee != comp:
                    merge(self.comp_collectives(callee), trip)
            if op not in _COLLECTIVE_OPS or "-done(" in ins:
                continue
            head = rest[: rest.index("(")]
            result = sum(_shape_bytes_match(mm) for mm in _SHAPE_RE.finditer(head))
            sm = _SHAPE_RE.search(head)
            dtype = sm.group(1) if sm else "?"
            K = self._group_size(ins)
            if op == "all-gather" or op == "all-to-all":
                wire = result * (K - 1) // K
            elif op == "reduce-scatter":
                wire = result * (K - 1)
            elif op == "all-reduce":
                wire = result * 2 * (K - 1) // K
            else:
                wire = result
            add(op, wire, result, dtype)
        self._memo_coll[comp] = acc
        return acc

    def collectives(self) -> dict:
        per_op = self.comp_collectives(self.entry)
        return {"per_op": per_op,
                "total_bytes": sum(d["wire_bytes"] for d in per_op.values()),
                "total_count": sum(d["count"] for d in per_op.values())}

    def flops(self) -> float:
        return self.comp_flops(self.entry)

    def bytes_accessed(self) -> float:
        return self.comp_bytes(self.entry)


def corrected_costs(hlo: str, n_dev: int = 1) -> dict:
    c = HloCost(hlo, n_dev=n_dev)
    out = {"flops": c.flops(), "bytes": c.bytes_accessed()}
    out["collectives"] = c.collectives()
    return out


# ---------------------------------------------------------------------------
# Agent-mesh combine budgets: deg·shard — NOT K·shard — on the wire
# ---------------------------------------------------------------------------

def tree_shard_bytes(shardings, abstracts, axis_sizes: dict[str, int],
                     elem_bytes: int | None = None) -> int:
    """Per-device bytes of a sharded pytree.

    ``shardings``: tree of NamedSharding (or anything with ``.spec``);
    ``abstracts``: matching tree of shaped/dtyped leaves;
    ``axis_sizes``: mesh axis extents.  Each leaf contributes
    ``nbytes / prod(extent of every mesh axis its PartitionSpec names)`` —
    the size of the block one device holds.  ``elem_bytes`` overrides each
    leaf's dtype itemsize; to size a combine's wire, pass
    ``diffusion.wire_elem_bytes(combine_dtype)`` — the ppermute rounds
    move the *wire* dtype (bf16 payloads travel as 2-byte u16, the f32
    escape hatch as 4-byte), not the stored param dtype."""
    import jax  # local import: this module must stay importable without
    import numpy as np  # touching jax device state (tests parse HLO text)
    total = 0
    for sh, ab in zip(jax.tree.leaves(shardings), jax.tree.leaves(abstracts),
                      strict=True):
        spec = getattr(sh, "spec", sh)
        div = 1
        for part in spec:
            for a in ((part,) if isinstance(part, str) else (part or ())):
                div *= axis_sizes.get(a, 1)
        item = ab.dtype.itemsize if elem_bytes is None else elem_bytes
        nbytes = int(np.prod(ab.shape, dtype=np.int64)) * item
        total += nbytes // div
    return total


def agent_combine_check(hlo: str, n_dev: int, *, degree: int,
                        shard_bytes: int, slack: float = 0.25,
                        wire_dtype: str | None = None) -> dict:
    """Verify the agent-axis combine's wire cost in post-SPMD HLO.

    Legacy entry point: the implementation moved to
    :func:`repro.analysis.rules.combine_window` (the one owner of the
    deg·shard window, shared with the ``collective-budget`` lint rule) —
    this shim delegates bit-for-bit.  Lazy import keeps this module's
    no-jax import contract and avoids a cycle (analysis.rules imports
    :class:`HloCost` from here)."""
    from repro.analysis.rules import combine_window
    return combine_window(hlo, n_dev, degree=degree,
                          shard_bytes=shard_bytes, slack=slack,
                          wire_dtype=wire_dtype)


# ---------------------------------------------------------------------------
# Fused outer-update HBM contract: the budget the one-pass kernel must hit
# ---------------------------------------------------------------------------

def fused_outer_update_bytes(n_elems: int, param_itemsize: int = 4, *,
                             optimizer: str = "adam",
                             grad_clip: bool = True) -> int:
    """Analytic HBM bytes/step of the fused combine-then-update kernel.

    One pass over the parameter set (P = n_elems · param_itemsize bytes;
    gradients share the param dtype; Adam moments are fp32, F = n_elems · 4;
    momentum's velocity lives in the param dtype): read params + grads,
    write params, plus one read + one write per optimizer moment, plus one
    extra gradient read for the pre-kernel global-norm clip reduction.
    Schedule tables, control scalars and the (K, 1) clip vector are
    O(K²·S) — noise next to the parameter bytes — and are excluded, exactly
    as the module docstring of ``kernels/dif_combine`` specifies.  Compare
    against ``HloCost.bytes_accessed()`` of the unfused chain (measured
    ≈15 P for f32 adam+clip+ATC) to report the fused/unfused traffic
    ratio: 4P + 4F → 0.53× at f32, 0.44× at bf16."""
    P = n_elems * param_itemsize
    F = n_elems * 4
    moments = {"sgd": 0, "momentum": 1, "adam": 2}[optimizer]
    mbytes = P if optimizer == "momentum" else F
    return 3 * P + (P if grad_clip else 0) + 2 * moments * mbytes
