"""Production mesh construction — the one owner of the mesh-axis contract.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single device.

Mesh-axis contract
==================

Every mesh in this repo is built from (a subset of) four named axes:

``agent``   one Dif-MAML learner per slice — the decentralized diffusion
            graph lives on this axis and on nothing else.  When present it
            is the leading axis, the ``agent`` *logical* axis of the
            stacked parameter tree maps onto it 1:1
            (``sharding/rules.py``), and the ``mesh_sparse`` /
            ``mesh_sparse_dynamic`` combine backends shard_map their
            ``lax.ppermute`` rounds over it (they require extent == K, one
            agent per shard — see :mod:`repro.core.diffusion`).
``data``    intra-agent batch/FSDP parallelism.  On legacy meshes without
            an ``agent`` axis it doubles as the agent axis for
            ``placement='data'`` archs (one agent per data slice).
``model``   tensor parallelism (ffn/heads/experts/vocab candidates in
            ``sharding/rules.py``); never carries agents.
``pod``     legacy multi-pod axis.  Before the ``agent`` axis existed,
            ``placement='pod'`` archs put one agent per pod and
            ``placement='data'`` archs tiled agents over ``(pod, data)``.
            On agent-axis meshes ``pod`` retires: the agent graph is
            ``agent`` and everything inside an agent is ``data``/``model``,
            regardless of ``cfg.placement``.

``make_production_mesh(agents=K)`` composes the axes at production scale:
each agent's K-th slice of the parameter stack is itself TP/FSDP-sharded
over the remaining ``data``/``model`` extents, which is what lets the big
configs (qwen2_7b, mixtral_8x22b, deepseek_v2_lite) run decentralized.
"""
from __future__ import annotations

import warnings

import jax

from repro import compat
from repro.compat import mesh_axis_sizes

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_axis_sizes"]

# One pod = 256 chips (16×16); the multi-pod budget doubles it.
_POD_DEVICES = 256


def make_production_mesh(*, multi_pod: bool = False,
                         agents: int | None = None,
                         model: int = 16) -> jax.sharding.Mesh:
    """Production mesh.

    ``agents=None`` (legacy): ``(data, model)`` = 16×16 single-pod or
    ``(pod, data, model)`` = 2×16×16 two-pod — the agent graph rides the
    ``data``/``pod`` axes per ``cfg.placement``.

    ``agents=K``: an agent-axis mesh over the same device budget (256
    single-pod, 512 with ``multi_pod``): ``(agent, data, model)`` with
    ``data = budget // (K · model)``, collapsing to 2D ``(agent, model)``
    when the data extent is 1.  ``K · model`` must divide the budget —
    a non-factoring request raises with both numbers instead of silently
    dropping devices.
    """
    if agents is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return compat.make_mesh(shape, axes)
    budget = 2 * _POD_DEVICES if multi_pod else _POD_DEVICES
    if agents < 1 or model < 1 or budget % (agents * model):
        raise ValueError(
            f"agent mesh does not factor: agents={agents} × model={model} "
            f"must divide the {budget}-device "
            f"{'two-pod' if multi_pod else 'single-pod'} budget "
            f"(got {agents * model})")
    data = budget // (agents * model)
    if data == 1:
        return compat.make_mesh((agents, model), ("agent", "model"))
    return compat.make_mesh((agents, data, model), ("agent", "data", "model"))


def make_host_mesh(data: int = 1, model: int = 1, *,
                   agents: int | None = None) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU examples).

    Legacy form: ``(data, model)``.  With ``agents=K``: the host-scale
    equivalent of the agent-aware production mesh — ``(agent, data,
    model)``, collapsing to ``(agent, model)`` when ``data == 1`` —
    requiring ``K · data · model`` to divide the device count exactly
    (agent-per-shard combine backends need the full extent, so a silent
    clamp would change K under the caller).

    A legacy request that does not factor over the available devices is
    clamped as before, but now *loudly*: a RuntimeWarning reports the
    requested and effective extents instead of silently dropping devices.
    """
    n = len(jax.devices())
    if agents is not None:
        if agents < 1 or data < 1 or model < 1 or n % (agents * data * model):
            raise ValueError(
                f"host agent mesh does not factor: agents={agents} × "
                f"data={data} × model={model} = {agents * data * model} "
                f"must divide the {n} available device(s)")
        if data == 1:
            return compat.make_mesh((agents, model), ("agent", "model"))
        return compat.make_mesh((agents, data, model),
                                ("agent", "data", "model"))
    eff_data = min(data, n)
    eff_model = max(1, min(model, n // eff_data))
    if (eff_data, eff_model) != (data, model) or n % (eff_data * eff_model):
        warnings.warn(
            f"make_host_mesh(data={data}, model={model}) does not factor "
            f"over the {n} available device(s); using "
            f"(data={eff_data}, model={eff_model}) — "
            f"{n - eff_data * eff_model} device(s) unused",
            RuntimeWarning, stacklevel=2)
    return compat.make_mesh((eff_data, eff_model), ("data", "model"))
