"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single device.
"""
from __future__ import annotations

import jax

from repro import compat
from repro.compat import mesh_axis_sizes

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    data = min(data, n)
    return compat.make_mesh(
        (data, max(1, min(model, n // data))), ("data", "model"))
