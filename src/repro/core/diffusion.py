"""Diffusion (Adapt-then-Combine) strategy over a stacked agent axis.

All per-agent launch models are stored with a leading ``K`` (agent) axis on
every parameter leaf.  The combine step (paper eq. 6b)

    w_{k,i} = Σ_l a_{lk} φ_{l,i}

is a contraction over that axis — the algorithm's only communication point.
This module is the single home for every implementation of that contraction,
organized as a **backend registry** behind one entry point,
:func:`make_combine`.  Trainer (``core/meta_trainer.py``), launch
(``launch/steps.py``) and benchmarks (``benchmarks/run.py``) all build their
combine through it.

Registered backends
===================

``dense``        einsum against the full K×K matrix.  Under pjit with the
                 agent axis sharded over a mesh axis, XLA lowers this to
                 all-gather + local reduction: O(K·|w|) collective bytes.
                 Paper-faithful baseline semantics for arbitrary graphs.
``sparse_host``  host-roll emulation of the ppermute schedule: one weighted
                 ``jnp.roll`` per circular neighbor offset.  Under GSPMD a
                 roll on the agent-sharded dim lowers to collective-permutes
                 of one shard per offset: O(deg·|w|) bytes.  Exact for *any*
                 A (offsets with partial support get elementwise-zero
                 weights), efficient when A is a union of few circular
                 offsets (ring, torus-on-agent-axis, full graph).
``sparse``       ``lax.ppermute`` schedule, to be called *inside* an
                 existing shard_map/manual context where the agent axis is
                 one-agent-per-shard.
``mesh_sparse``  production sparse combine: the ``sparse`` schedule wrapped
                 in a partial-manual shard_map over the agent mesh axis
                 (built via :mod:`repro.compat`, so it runs on jax 0.4.x
                 and >= 0.5 alike).  Requires jit.
``sparse_host_dynamic``
                 host-roll lowering of a *dynamic* (stacked ``(S, K, K)``)
                 schedule via its :class:`repro.core.topology.ScheduleIR`:
                 one weighted roll per offset in the period's offset
                 *union*, with the per-step weight rows gathered by the
                 traced step index.  Exact for every schedule kind
                 (inactive offsets carry zero weights that step); under
                 GSPMD each roll stays a collective-permute, so dynamic
                 graphs keep O(deg·|w|) wire instead of the O(K·|w|) the
                 dense step-indexed einsum pays.
``sparse_dynamic``
                 the same IR lowered to ``lax.ppermute`` rounds, to be
                 called *inside* an existing shard_map/manual context
                 (one-agent-per-shard); the permute set is fixed across
                 steps — only the weight gather sees the step — so one
                 jitted program serves the whole schedule.
``mesh_sparse_dynamic``
                 production dynamic combine: ``sparse_dynamic`` wrapped in
                 a partial-manual shard_map over the agent mesh axis, step
                 threaded in replicated.  Requires jit.
``pallas``       the fused :mod:`repro.kernels.dif_combine` TPU kernel:
                 one pass over the parameter bytes instead of K−1 separate
                 axpy passes.  Arbitrary parameter pytrees are served
                 through the flatten-to-(K, M) pack/unpack path below
                 (lane-aligned zero padding, one kernel launch per dtype
                 group).  ``interpret=True`` runs the same kernel on CPU.
``centralized``  every agent receives the centroid (fully-connected uniform
                 A = (1/K)11ᵀ): the paper's centralized reference.
``none``         identity: the non-cooperative baseline (A = I).

Agent mesh axis
===============

The ``mesh_sparse`` / ``mesh_sparse_dynamic`` backends require the agent
axis they shard_map over to hold exactly one agent per shard (extent == K).
Two mesh generations satisfy this (the full contract lives in
``launch/mesh.py``):

* legacy meshes, where the agent graph rides ``data`` (or ``pod`` for
  ``placement='pod'`` archs) — valid only when that axis extent equals K;
* agent-axis meshes (``make_production_mesh(agents=K)``), where ``agent``
  is a dedicated leading axis composed with intra-agent ``data`` (FSDP)
  and ``model`` (TP) axes.  Here ``in_specs`` must carry each leaf's real
  sharding (agent axis *plus* its TP/FSDP axes) so the ppermute rounds
  move only the per-agent *shard* — deg·(per-device shard bytes) on the
  wire — while the model-axis collectives of the surrounding step stay
  untouched.  :func:`select_backend` defaults ``axis_name`` to ``'agent'``
  on such meshes.

Wire format
===========

The ppermute backends (``sparse``/``mesh_sparse`` and their ``*_dynamic``
siblings) take a ``combine_dtype`` — the dtype φ travels in on the
collective-permute rounds:

``"bfloat16"``   half-width wire.  Each leaf is rounded to bf16 **once**
                 and bitcast to ``uint16`` before the permute rounds, so
                 no backend pass can silently widen the transfer (XLA:CPU's
                 float normalization upcasts bf16 collectives to f32;
                 integer collectives are left alone on every backend, and
                 on TPU the bitcast is free).  Every received payload is
                 bitcast back and the weighted mix is **accumulated in
                 f32**, with the self-term taken from the local full-
                 precision value — one rounding on the wire, none
                 compounding across rounds — then cast back to the leaf
                 dtype once.  Combine wire bytes drop 2× vs the f32 wire.
``"float32"``    full-width wire: φ is promoted to f32 for the rounds and
                 the mix accumulates in f32 (the escape hatch when bf16
                 parity is in question).
``None``         legacy behavior: rounds and accumulation in the leaf's
                 own dtype (kept for direct callers; the launch layer
                 always resolves a concrete wire dtype).

:func:`resolve_combine_dtype` owns the default: the wire is bf16 exactly
when the outer (param/grad) dtype is bf16, and ``--combine-dtype f32``
overrides it.  :func:`wire_elem_bytes` maps the resolved name to the
per-element wire bytes the budget checks (``tree_shard_bytes`` /
``agent_combine_check`` / ``AGENT_MESH_BUDGETS``) must size against.

Backend selection
=================

``make_combine("auto", A=A, mesh=..., axis_name=...)`` picks by topology,
mesh and accelerator:

  1. K == 1                                  → ``none``
  2. stacked ``(S, K, K)`` schedule whose offset union is sparse
     (deg < K−1): on a live mesh whose ``axis_name`` extent equals K
     → ``mesh_sparse_dynamic``; otherwise    → ``sparse_host_dynamic``
  3. stacked schedule with a dense offset union (e.g. gossip on the
     full graph)                             → ``dense`` (step-indexed)
  4. circular-offset-sparse static A (deg < K−1) on a live mesh whose
     ``axis_name`` extent equals K           → ``mesh_sparse``
  5. circular-offset-sparse static A, no mesh → ``sparse_host``
  6. dense A, no mesh, TPU backend           → ``pallas``
     (on a live mesh the packed layout would break leaf shardings,
     so dense-einsum keeps the GSPMD lowering)
  7. otherwise                               → ``dense``

:func:`resolve_schedule_backend` routes an explicitly-requested static
sparse backend (``sparse``/``sparse_host``/``mesh_sparse``) to its
``*_dynamic`` sibling when the matrix is a stacked schedule — the permute
rounds and wire cost are identical, only the weight gather becomes
step-indexed — and only falls back to ``dense`` (loudly) for backends with
no dynamic form.

Supported JAX versions: 0.4.x (tested on 0.4.37) and >= 0.5 — every
version-sensitive construct (shard_map flavor, AbstractMesh constructor)
goes through :mod:`repro.compat`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

PyTree = Any
# Every registered backend returns ``combine(phi, step=None)``: the optional
# traced step index selects the current matrix of a stacked ``(S, K, K)``
# schedule (static matrices ignore it), so dynamic graphs stay inside one
# jit-compiled step function.
CombineFn = Callable[..., PyTree]

__all__ = [
    "resolve_combine_dtype",
    "wire_elem_bytes",
    "dense_combine",
    "sparse_combine_host",
    "make_sparse_combine",
    "make_mesh_sparse_combine",
    "make_sparse_host_dynamic_combine",
    "make_sparse_dynamic_combine",
    "make_mesh_sparse_dynamic_combine",
    "make_pallas_combine",
    "pack_pytree",
    "centralized_combine",
    "no_combine",
    "CombineBackend",
    "register_backend",
    "combine_backends",
    "select_backend",
    "resolve_schedule_backend",
    "make_combine",
    "atc_step",
    "cta_step",
    "disagreement",
    "centroid",
]

LANE = 128                 # TPU vector lane width; pallas pad granularity

# Wire dtypes the ppermute backends can put on the combine rounds, with the
# per-element wire bytes every budget check must size against.
WIRE_DTYPES = {"bfloat16": 2, "float32": 4}


def resolve_combine_dtype(outer_dtype: str, override: str | None = None
                          ) -> str:
    """The wire dtype of the sparse combine rounds (module docstring, "Wire
    format"): bf16 exactly when the outer (param/grad) dtype is bf16, f32
    otherwise; ``override`` (the ``--combine-dtype`` escape hatch) wins."""
    chosen = override or ("bfloat16" if outer_dtype == "bfloat16"
                          else "float32")
    if chosen not in WIRE_DTYPES:
        raise ValueError(
            f"combine_dtype {chosen!r} is not a supported wire format; "
            f"pick one of {sorted(WIRE_DTYPES)}")
    return chosen


def wire_elem_bytes(combine_dtype: str) -> int:
    """Per-element bytes the combine's permute rounds put on the wire."""
    return WIRE_DTYPES[combine_dtype]


def _wire_encode(x):
    """One rounding to bf16, shipped as its u16 bit pattern: integer
    collectives dodge every float-widening backend pass (XLA:CPU's float
    normalization upcasts bf16 collectives to f32), so the permute result
    is 2 bytes/elem in the *optimized* HLO on every backend."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)


def _wire_decode(r):
    """Received u16 payload -> f32 for the accumulation."""
    return jax.lax.bitcast_convert_type(r, jnp.bfloat16).astype(jnp.float32)


def _circular_offsets(A: np.ndarray) -> list[int]:
    """Offsets d in [1, K) with any nonzero weight a_{(k-d) mod K, k}."""
    K = A.shape[0]
    return [d for d in range(1, K)
            if any(A[(k - d) % K, k] > 0 for k in range(K))]


# ---------------------------------------------------------------------------
# Combine implementations
# ---------------------------------------------------------------------------

def dense_combine(A: jax.Array, phi: PyTree) -> PyTree:
    """w_new[k] = Σ_l A[l, k] φ[l] on the leading agent axis of each leaf."""

    def leaf(x):
        return jnp.einsum("lk,l...->k...", A.astype(x.dtype), x)

    return jax.tree.map(leaf, phi)


def sparse_combine_host(A: np.ndarray, phi: PyTree) -> PyTree:
    """Single-host emulation of the ppermute schedule using jnp.roll.

    Identical math to :func:`make_sparse_combine`; under GSPMD with the
    agent dim sharded, each roll lowers to a collective-permute while every
    other (TP) dim keeps its sharding.
    """
    A = np.asarray(A)
    K = A.shape[0]
    offsets = _circular_offsets(A)
    self_w = jnp.asarray(np.diagonal(A).copy())
    off_w = {d: jnp.asarray(np.array([A[(k - d) % K, k] for k in range(K)]))
             for d in offsets}

    def leaf(x):
        shape = (K,) + (1,) * (x.ndim - 1)
        acc = x * self_w.astype(x.dtype).reshape(shape)
        for d in offsets:
            # agent k receives from agent (k - d) mod K  ==  roll by +d
            acc = acc + (off_w[d].astype(x.dtype).reshape(shape)
                         * jnp.roll(x, d, axis=0))
        return acc

    return jax.tree.map(leaf, phi)


def make_sparse_combine(A: np.ndarray, axis_name: str,
                        wire_dtype: str | None = None) -> CombineFn:
    """Collective-permute combine, to be called *inside* shard_map where the
    leading agent axis is sharded one-agent-per-shard over ``axis_name``.

    Each circular offset ``d`` with any nonzero weight contributes one
    ``lax.ppermute`` (collective-permute over ICI) plus a per-destination
    weight multiply.  Self weights are a local scale.  Total collective
    bytes = (#offsets) · wire_elem_bytes · |w| vs. (K-1)/K · K · |w| for
    the all-gather that XLA emits for the dense einsum.

    ``wire_dtype``: the wire-format contract of the module docstring —
    'bfloat16' ships each leaf's one-time bf16 rounding as u16 and
    accumulates the mix in f32; 'float32' promotes to f32 for the rounds;
    None keeps the legacy in-dtype math."""
    A = np.asarray(A)
    K = A.shape[0]
    offsets = _circular_offsets(A)
    self_w = np.diagonal(A).copy()
    off_w = {d: np.array([A[(k - d) % K, k] for k in range(K)]) for d in offsets}
    half = wire_dtype == "bfloat16"

    def combine(phi: PyTree) -> PyTree:
        k = jax.lax.axis_index(axis_name)

        def leaf(x):
            # x: local block (1, ...) — one agent per shard.
            if wire_dtype is None:
                acc = x * jnp.asarray(self_w, x.dtype)[k]
                for d in offsets:
                    perm = [(l, (l + d) % K) for l in range(K)]
                    recv = jax.lax.ppermute(x, axis_name, perm)
                    acc = acc + recv * jnp.asarray(off_w[d], x.dtype)[k]
                return acc
            # f32 accumulation; only neighbor terms pass through the wire
            send = _wire_encode(x) if half else x.astype(jnp.float32)
            acc = x.astype(jnp.float32) * jnp.asarray(self_w, jnp.float32)[k]
            for d in offsets:
                perm = [(l, (l + d) % K) for l in range(K)]
                recv = jax.lax.ppermute(send, axis_name, perm)
                r32 = _wire_decode(recv) if half else recv
                acc = acc + r32 * jnp.asarray(off_w[d], jnp.float32)[k]
            return acc.astype(x.dtype)

        return jax.tree.map(leaf, phi)

    return combine


def make_mesh_sparse_combine(A: np.ndarray, mesh, axis_name: str,
                             in_specs: PyTree | None = None,
                             wire_dtype: str | None = None) -> CombineFn:
    """Production sparse combine: shard_map over the agent mesh axis with the
    ppermute schedule of :func:`make_sparse_combine`.  The agent axis is
    manual; all other axes (e.g. 'model' tensor parallelism) stay auto.
    Partial-manual shard_map must run under jit (both JAX lines).

    ``in_specs``: pytree of PartitionSpecs matching phi's *actual* shardings
    (agent dim on ``axis_name`` plus whatever TP axes each leaf carries).
    Omitting the TP axes would make shard_map all-gather every TP-sharded
    parameter at entry — measured +77% step wire bytes on qwen2-1.5b — so
    callers must pass the real specs for TP-sharded trees.

    Wire bytes per device for the exchange itself: (#circular offsets) ×
    |w_local|, vs. (K−1)/K × K × |w_local| for the dense-einsum all-gather."""
    from jax.sharding import PartitionSpec as _P

    inner = make_sparse_combine(A, axis_name, wire_dtype=wire_dtype)
    specs = in_specs if in_specs is not None else _P(axis_name)
    # Every axis the specs mention must be manual; any remaining mesh axis
    # stays auto (partial-manual mode — fine on TPU, but XLA:CPU cannot
    # partition it, so CPU callers should pass specs covering their axes).
    manual = {axis_name}
    for s in compat.tree_leaves(specs, is_leaf=lambda x: isinstance(x, _P)):
        for part in s:
            if part is not None:
                manual.update((part,) if isinstance(part, str) else part)

    def combine(phi: PyTree) -> PyTree:
        return compat.shard_map(
            inner, mesh, in_specs=(specs,), out_specs=specs,
            axis_names=manual)(phi)

    return combine


# ---------------------------------------------------------------------------
# Dynamic-schedule sparse combines: fixed ppermute rounds over the period's
# offset union, per-step weights gathered with the traced step index
# ---------------------------------------------------------------------------

def _ir_for(A):
    """Accept a ScheduleIR, a (K, K) matrix, or a stacked (S, K, K)
    schedule and return the ScheduleIR lowering."""
    from repro.core import topology
    if isinstance(A, topology.ScheduleIR):
        return A
    return topology.schedule_ir(np.asarray(A))


def _schedule_step(step, S: int):
    """The traced row index into the (S, ...) weight tables."""
    if step is None:
        if S != 1:
            raise ValueError(
                "a dynamic matrix schedule needs the step index: call "
                "combine(phi, step)")
        return jnp.zeros((), jnp.int32)
    return jnp.mod(step, S)


def make_sparse_host_dynamic_combine(ir) -> CombineFn:
    """Host-roll lowering of a dynamic schedule: one weighted ``jnp.roll``
    per offset in the period's union, weights gathered at ``step % S``.

    Identical math to the dense step-indexed einsum for *every* schedule
    kind (an offset inactive at some step carries elementwise-zero weights
    there).  Under GSPMD with the agent dim sharded each roll lowers to a
    collective-permute of one shard — O(deg·|w|) wire per combine, where
    deg is the offset-union size, vs O(K·|w|) for the dense gather."""
    K, S, offsets = ir.K, ir.period, ir.offsets
    self_w = jnp.asarray(ir.self_weights)        # (S, K)
    off_w = jnp.asarray(ir.offset_weights)       # (S, D, K)

    def combine(phi: PyTree, step=None) -> PyTree:
        s = _schedule_step(step, S)
        sw = jax.lax.dynamic_index_in_dim(self_w, s, keepdims=False)
        ow = jax.lax.dynamic_index_in_dim(off_w, s, keepdims=False)

        def leaf(x):
            shape = (K,) + (1,) * (x.ndim - 1)
            acc = x * sw.astype(x.dtype).reshape(shape)
            for i, d in enumerate(offsets):
                # agent k receives from agent (k - d) mod K == roll by +d
                acc = acc + (ow[i].astype(x.dtype).reshape(shape)
                             * jnp.roll(x, d, axis=0))
            return acc

        return jax.tree.map(leaf, phi)

    return combine


def make_sparse_dynamic_combine(ir, axis_name: str,
                                wire_dtype: str | None = None) -> CombineFn:
    """``lax.ppermute`` lowering of a dynamic schedule, to be called
    *inside* shard_map with the agent axis one-agent-per-shard over
    ``axis_name``.

    The permute set is the period's offset union — fixed across steps, so
    the whole schedule compiles to one program; only the weight gather
    (two scalar loads per round from the (S, ·, K) tables) sees the step.
    Wire bytes per combine: D · wire_elem_bytes · |w_local| with D = deg
    of the union.  ``wire_dtype`` follows the module-docstring wire-format
    contract (None = legacy in-dtype math)."""
    K, S, offsets = ir.K, ir.period, ir.offsets
    np_self_w = np.asarray(ir.self_weights, np.float32)     # (S, K)
    np_off_w = np.asarray(ir.offset_weights, np.float32)    # (S, D, K)
    half = wire_dtype == "bfloat16"

    def combine(phi: PyTree, step=None) -> PyTree:
        s = _schedule_step(step, S)
        k = jax.lax.axis_index(axis_name)
        sw = jnp.asarray(np_self_w)[s, k]
        ow = jnp.asarray(np_off_w)[s, :, k]      # (D,) this agent's weights

        def leaf(x):
            if wire_dtype is None:
                acc = x * sw.astype(x.dtype)
                for i, d in enumerate(offsets):
                    perm = [(l, (l + d) % K) for l in range(K)]
                    recv = jax.lax.ppermute(x, axis_name, perm)
                    acc = acc + recv * ow[i].astype(x.dtype)
                return acc
            # f32 accumulation; only neighbor terms pass through the wire
            send = _wire_encode(x) if half else x.astype(jnp.float32)
            acc = x.astype(jnp.float32) * sw
            for i, d in enumerate(offsets):
                perm = [(l, (l + d) % K) for l in range(K)]
                recv = jax.lax.ppermute(send, axis_name, perm)
                r32 = _wire_decode(recv) if half else recv
                acc = acc + r32 * ow[i]
            return acc.astype(x.dtype)

        return jax.tree.map(leaf, phi)

    return combine


def make_mesh_sparse_dynamic_combine(ir, mesh, axis_name: str,
                                     in_specs: PyTree | None = None,
                                     wire_dtype: str | None = None
                                     ) -> CombineFn:
    """Production dynamic combine: shard_map over the agent mesh axis with
    the :func:`make_sparse_dynamic_combine` rounds; the step index rides in
    replicated.  Same in_specs contract as :func:`make_mesh_sparse_combine`
    (pass the real leaf specs for TP-sharded trees or shard_map all-gathers
    them at entry)."""
    from jax.sharding import PartitionSpec as _P

    inner = make_sparse_dynamic_combine(ir, axis_name, wire_dtype=wire_dtype)
    specs = in_specs if in_specs is not None else _P(axis_name)
    manual = {axis_name}
    for s in compat.tree_leaves(specs, is_leaf=lambda x: isinstance(x, _P)):
        for part in s:
            if part is not None:
                manual.update((part,) if isinstance(part, str) else part)

    def combine(phi: PyTree, step=None) -> PyTree:
        if step is None:
            _schedule_step(step, ir.period)      # raise early when S > 1
            step = jnp.zeros((), jnp.int32)
        return compat.shard_map(
            inner, mesh, in_specs=(specs, _P()), out_specs=specs,
            axis_names=manual)(phi, step)

    return combine


def centralized_combine(phi: PyTree) -> PyTree:
    """All agents receive the network centroid: A = (1/K) 1 1ᵀ."""

    def leaf(x):
        return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)

    return jax.tree.map(leaf, phi)


def no_combine(phi: PyTree) -> PyTree:
    return phi


# ---------------------------------------------------------------------------
# Pallas backend: flatten-to-(K, M) pack/unpack so the fused kernel serves
# arbitrary parameter pytrees (ragged leaf sizes, mixed dtypes)
# ---------------------------------------------------------------------------

def pack_pytree(phi: PyTree, block_m: int = 512
                ) -> tuple[list[jax.Array], Callable[[list[jax.Array]], PyTree]]:
    """Pack a pytree of (K, ...) leaves into one (K, M_pad) buffer per dtype.

    Leaves are flattened to (K, m_i) and concatenated along the feature dim,
    then zero-padded so M_pad is the smallest multiple of ``block_m`` (keep
    ``block_m`` a multiple of the 128-lane width for full-width VPU
    reductions) covering the group.  Because the combine is linear and the
    pad is zero, padded columns stay zero through the kernel and are sliced
    off on unpack.

    Returns ``(buffers, unpack)`` where ``unpack`` maps same-shaped combined
    buffers back to the original pytree structure.
    """
    leaves, treedef = jax.tree.flatten(phi)
    if not leaves:
        return [], lambda bufs: jax.tree.unflatten(treedef, [])
    K = leaves[0].shape[0]
    groups: dict[Any, list[int]] = {}
    for i, x in enumerate(leaves):
        groups.setdefault(jnp.dtype(x.dtype), []).append(i)

    buffers: list[jax.Array] = []
    layout: list[tuple[list[int], list[tuple[int, ...]]]] = []
    for dt, idxs in groups.items():
        flats = [leaves[i].reshape(K, -1) for i in idxs]
        M = sum(f.shape[1] for f in flats)
        pad = (-M) % block_m
        if pad:
            flats.append(jnp.zeros((K, pad), dt))
        buffers.append(jnp.concatenate(flats, axis=1) if len(flats) > 1
                       else flats[0])
        layout.append((idxs, [leaves[i].shape for i in idxs]))

    def unpack(new_buffers: list[jax.Array]) -> PyTree:
        out: list[Any] = list(leaves)
        for buf, (idxs, shapes) in zip(new_buffers, layout):
            off = 0
            for i, shape in zip(idxs, shapes):
                n = int(np.prod(shape[1:], dtype=np.int64))
                out[i] = jax.lax.slice_in_dim(buf, off, off + n,
                                              axis=1).reshape(shape)
                off += n
        return jax.tree.unflatten(treedef, out)

    return buffers, unpack


def make_pallas_combine(A: np.ndarray | jax.Array, *, block_m: int = 512,
                        interpret: bool | None = None) -> CombineFn:
    """Fused dif_combine kernel over the packed (K, M) layout.

    ``interpret=None`` auto-detects: compiled on TPU, interpreter elsewhere
    (bitwise-identical math, lets CPU tests exercise the production path).
    """
    Aj = jnp.asarray(A)

    def combine(phi: PyTree) -> PyTree:
        return _pallas_apply(Aj, phi, block_m=block_m, interpret=interpret)

    return combine


# ---------------------------------------------------------------------------
# Backend registry + selection
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CombineBackend:
    """One registered combine implementation.

    ``build(A=..., axis_name=..., mesh=..., in_specs=..., block_m=...,
    interpret=...)`` returns a ``CombineFn``; builders ignore context keys
    they don't need.
    """
    name: str
    build: Callable[..., CombineFn]
    needs_matrix: bool = True
    needs_mesh: bool = False
    needs_axis_name: bool = False
    # Whether the lowered combine moves its payload over collective-permutes
    # — the backends the wire-dtype / deg·shard lint rules can reason about.
    # Dense/pallas/centralized combines exchange nothing (replicated math)
    # or use other collectives, so permute-based rules skip them.
    emits_permutes: bool = False


_BACKENDS: dict[str, CombineBackend] = {}


def register_backend(name: str, **flags: bool):
    """Decorator: register a combine builder under ``name``."""

    def deco(build: Callable[..., CombineFn]) -> Callable[..., CombineFn]:
        _BACKENDS[name] = CombineBackend(name, build, **flags)
        return build

    return deco


def combine_backends() -> tuple[str, ...]:
    return tuple(_BACKENDS)


def backend_lint_metadata(name: str, combine_dtype: str | None = None) -> dict:
    """What the compiled-program lint rules may assume about a backend.

    ``emits_permutes`` gates the permute-window rules (a dense/pallas
    combine exchanges nothing over collective-permutes); ``wire_hlo_dtype``
    is the HLO-level dtype the payload travels in — ``u16`` for the bf16
    bitcast wire, ``f32`` otherwise (see the wire-format contract above).
    """
    if name not in _BACKENDS:
        raise ValueError(
            f"unknown combine backend {name!r}; "
            f"pick one of {sorted(_BACKENDS)}")
    b = _BACKENDS[name]
    return {
        "backend": name,
        "emits_permutes": b.emits_permutes,
        "wire_hlo_dtype": "u16" if combine_dtype == "bfloat16" else "f32",
    }


def _stepless(fn: Callable[[PyTree], PyTree]) -> CombineFn:
    """Adapt a static combine to the ``(phi, step=None)`` surface."""

    def combine(phi: PyTree, step=None) -> PyTree:
        return fn(phi)

    return combine


def _stacked(Aj: jax.Array, apply: Callable[[jax.Array, PyTree], PyTree]
             ) -> CombineFn:
    """Index a stacked ``(S, K, K)`` schedule with the traced step, then
    run ``apply(A_t, phi)`` — shared by every step-indexed backend."""
    S = Aj.shape[0]

    def combine(phi: PyTree, step=None) -> PyTree:
        if step is None:
            raise ValueError(
                "a stacked matrix schedule needs the step index: call "
                "combine(phi, step)")
        At = jax.lax.dynamic_index_in_dim(Aj, jnp.mod(step, S),
                                          keepdims=False)
        return apply(At, phi)

    return combine


# Static sparse backend -> its stacked-schedule-capable sibling: the same
# ppermute rounds, with the per-step weight rows gathered by the traced step.
_DYNAMIC_SIBLING = {"sparse": "sparse_dynamic",
                    "sparse_host": "sparse_host_dynamic",
                    "mesh_sparse": "mesh_sparse_dynamic"}


def _reject_stacked(A, name: str) -> np.ndarray:
    A = np.asarray(A)
    if A.ndim == 3:
        raise ValueError(
            f"combine backend {name!r} precomputes a static per-offset "
            f"permute schedule and cannot serve a stacked ({A.shape[0]}-"
            f"step) matrix schedule; use its dynamic sibling "
            f"{_DYNAMIC_SIBLING[name]!r} (same O(deg·|w|) ppermute rounds, "
            f"weights gathered with the traced step) — or the step-indexed "
            f"'dense'/'pallas' dense fallbacks")
    return A


@register_backend("dense")
def _build_dense(*, A, **_ctx) -> CombineFn:
    Aj = jnp.asarray(A)
    if Aj.ndim == 3:
        return _stacked(Aj, dense_combine)
    return _stepless(functools.partial(dense_combine, Aj))


@register_backend("sparse_host", emits_permutes=True)
def _build_sparse_host(*, A, **_ctx) -> CombineFn:
    return _stepless(functools.partial(
        sparse_combine_host, _reject_stacked(A, "sparse_host")))


@register_backend("sparse", needs_axis_name=True, emits_permutes=True)
def _build_sparse(*, A, axis_name, combine_dtype=None, **_ctx) -> CombineFn:
    return _stepless(make_sparse_combine(_reject_stacked(A, "sparse"),
                                         axis_name, wire_dtype=combine_dtype))


@register_backend("mesh_sparse", needs_mesh=True, needs_axis_name=True,
                  emits_permutes=True)
def _build_mesh_sparse(*, A, mesh, axis_name, in_specs=None,
                       combine_dtype=None, **_ctx) -> CombineFn:
    A = _reject_stacked(A, "mesh_sparse")
    K = A.shape[0]
    _check_agent_extent("mesh_sparse", mesh, axis_name, K)
    return _stepless(make_mesh_sparse_combine(A, mesh, axis_name,
                                              in_specs=in_specs,
                                              wire_dtype=combine_dtype))


def _check_agent_extent(name: str, mesh, axis_name: str, K: int) -> None:
    extent = compat.mesh_axis_sizes(mesh).get(axis_name)
    if extent != K:
        raise ValueError(
            f"{name} needs one agent per shard: axis {axis_name!r} has "
            f"extent {extent} but the schedule is over K={K} agents. Use "
            f"'sparse_host{'_dynamic' if 'dynamic' in name else ''}' when "
            f"the agent axis spans multiple mesh axes (e.g. multi-pod data "
            f"placement).")


@register_backend("sparse_host_dynamic", emits_permutes=True)
def _build_sparse_host_dynamic(*, A, **_ctx) -> CombineFn:
    return make_sparse_host_dynamic_combine(_ir_for(A))


@register_backend("sparse_dynamic", needs_axis_name=True,
                  emits_permutes=True)
def _build_sparse_dynamic(*, A, axis_name, combine_dtype=None, **_ctx
                          ) -> CombineFn:
    return make_sparse_dynamic_combine(_ir_for(A), axis_name,
                                       wire_dtype=combine_dtype)


@register_backend("mesh_sparse_dynamic", needs_mesh=True,
                  needs_axis_name=True, emits_permutes=True)
def _build_mesh_sparse_dynamic(*, A, mesh, axis_name, in_specs=None,
                               combine_dtype=None, **_ctx) -> CombineFn:
    ir = _ir_for(A)
    _check_agent_extent("mesh_sparse_dynamic", mesh, axis_name, ir.K)
    return make_mesh_sparse_dynamic_combine(ir, mesh, axis_name,
                                            in_specs=in_specs,
                                            wire_dtype=combine_dtype)


@register_backend("pallas")
def _build_pallas(*, A, block_m=512, interpret=None, **_ctx) -> CombineFn:
    Aj = jnp.asarray(A)
    if Aj.ndim == 3:
        return _stacked(Aj, functools.partial(_pallas_apply, block_m=block_m,
                                              interpret=interpret))
    return _stepless(make_pallas_combine(Aj, block_m=block_m,
                                         interpret=interpret))


@register_backend("fused")
def _build_fused(*, A, block_m=512, interpret=None, **_ctx) -> CombineFn:
    """Combine-only face of the fused outer backend.

    Selecting ``backend='fused'`` moves the whole clip→moments→combine
    chain into :func:`repro.core.fused.make_fused_outer` — the trainer
    threads that path itself.  The registry entry exists for the two spots
    that still need a plain combine under that name: the cta pre-mix (which
    runs *before* the gradient and therefore cannot fuse with the update)
    and direct ``make_combine('fused')`` callers; both get the packed
    one-pass pallas combine."""
    return _build_pallas(A=A, block_m=block_m, interpret=interpret)


def _pallas_apply(A: jax.Array, phi: PyTree, *, block_m: int = 512,
                  interpret: bool | None = None) -> PyTree:
    """One pallas combine against an already-selected (possibly traced)
    matrix."""
    from repro.kernels.dif_combine.dif_combine import dif_combine

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    buffers, unpack = pack_pytree(phi, block_m=block_m)
    outs = [dif_combine(A, buf, block_m=block_m, interpret=interpret)
            for buf in buffers]
    return unpack(outs)


@register_backend("centralized", needs_matrix=False)
def _build_centralized(**_ctx) -> CombineFn:
    return _stepless(centralized_combine)


@register_backend("none", needs_matrix=False)
def _build_none(**_ctx) -> CombineFn:
    return _stepless(no_combine)


def select_backend(A: np.ndarray | None, *, mesh=None,
                   axis_name: str | None = None) -> str:
    """Pick a backend name from topology, mesh and accelerator (see module
    docstring for the rule table).

    A mesh with a first-class ``agent`` axis announces the agent extent
    itself: when ``axis_name`` is not given it defaults to ``'agent'`` on
    such meshes, so 2D ``(agent, model)`` production meshes route sparse
    topologies to the shard_mapped backends without the caller having to
    know which mesh generation it is on."""
    if mesh is not None and axis_name is None:
        if "agent" in getattr(mesh, "axis_names", ()):
            axis_name = "agent"
    if A is None:
        return "dense"
    from repro.core import topology as _topo
    if isinstance(A, _topo.ScheduleIR):
        A = A.stacked()
    A = np.asarray(A)
    if A.ndim == 3:
        # stacked per-step schedule: a sparse offset union lowers to fixed
        # ppermute rounds with step-gathered weights; a dense union (e.g.
        # gossip on the full graph) keeps the step-indexed dense einsum
        ir = _ir_for(A)
        if ir.K == 1:
            return "none"
        if ir.degree < ir.K - 1:
            if (mesh is not None and axis_name is not None
                    and compat.mesh_axis_sizes(mesh).get(axis_name) == ir.K):
                return "mesh_sparse_dynamic"
            return "sparse_host_dynamic"
        return "dense"
    K = A.shape[0]
    if K == 1:
        return "none"
    degree = len(_circular_offsets(A))
    sparse_wins = degree < K - 1          # strictly fewer collectives than
    if sparse_wins and mesh is not None and axis_name is not None:
        if compat.mesh_axis_sizes(mesh).get(axis_name) == K:
            return "mesh_sparse"
    if sparse_wins:
        return "sparse_host"
    if mesh is None and jax.default_backend() == "tpu":
        # fused one-pass dense reduction; only off-mesh — pack_pytree's
        # concatenate would destroy leaf shardings on a live mesh, forcing
        # an all-gather of every TP shard
        return "pallas"
    return "dense"


# Backends able to serve a stacked (S, K, K) schedule with the traced step.
_STEP_INDEXED_BACKENDS = ("dense", "pallas", "fused", "sparse_dynamic",
                          "sparse_host_dynamic", "mesh_sparse_dynamic")


def resolve_schedule_backend(backend: str, A) -> str:
    """Route ``backend`` to a stacked-schedule-capable equivalent when ``A``
    is a stacked schedule ('auto' resolves itself in
    :func:`select_backend`).  The single owner of the capability list —
    trainer and launch both route through here.

    The static sparse backends upgrade silently to their ``*_dynamic``
    siblings: identical permute rounds and O(deg·|w|) wire, only the weight
    gather becomes step-indexed.  A backend with no dynamic form falls back
    to 'dense' — loudly, because that gives up the sparse wire cost."""
    if (backend != "auto" and A is not None
            and np.asarray(A).ndim == 3
            and backend not in _STEP_INDEXED_BACKENDS):
        b = _BACKENDS.get(backend)
        if b is not None and not b.needs_matrix:
            return backend           # matrix-free (none/centralized): no-op
        sibling = _DYNAMIC_SIBLING.get(backend)
        if sibling is not None:
            return sibling
        import warnings
        warnings.warn(
            f"combine backend {backend!r} cannot step-index a stacked "
            f"({np.asarray(A).shape[0]}-step) matrix schedule; falling back "
            f"to 'dense' — collective bytes rise from O(deg·|w|) to "
            f"O(K·|w|). Use a static schedule to keep {backend!r}.",
            RuntimeWarning, stacklevel=3)
        return "dense"
    return backend


def make_combine(strategy: str, A: np.ndarray | None = None,
                 axis_name: str | None = None, *, mesh=None,
                 in_specs: PyTree | None = None, block_m: int = 512,
                 interpret: bool | None = None,
                 combine_dtype: str | None = None) -> CombineFn:
    """Single entry point: build a combine fn from a backend name or 'auto'.

    ``strategy``: 'auto' | any :func:`combine_backends` name.  'auto'
    resolves via :func:`select_backend`.

    ``A`` may be one ``(K, K)`` matrix, a stacked ``(S, K, K)`` schedule
    (see :class:`repro.core.topology.TopologySchedule`), or — for the
    ``*_dynamic`` backends — a pre-lowered
    :class:`repro.core.topology.ScheduleIR`.  Stacked schedules are served
    at O(deg·|w|) wire by the ``sparse_dynamic`` family (fixed ppermute
    rounds, weights gathered with the step passed to
    ``combine(phi, step)``) and at O(K·|w|) by the step-indexed
    'dense'/'pallas' fallbacks.

    ``combine_dtype``: wire format for the ppermute backends (see the
    module docstring) — 'bfloat16' | 'float32' | None (legacy in-dtype).
    Backends without a wire (dense, pallas, host rolls, …) ignore it.
    """
    if strategy == "auto":
        strategy = select_backend(A, mesh=mesh, axis_name=axis_name)
    if combine_dtype is not None and combine_dtype not in WIRE_DTYPES:
        raise ValueError(
            f"combine_dtype {combine_dtype!r} is not a supported wire "
            f"format; pick one of {sorted(WIRE_DTYPES)}")
    backend = _BACKENDS.get(strategy)
    if backend is None:
        raise ValueError(
            f"unknown combine strategy {strategy!r}; "
            f"registered: {combine_backends()}")
    if backend.needs_matrix:
        assert A is not None, f"{strategy!r} combine needs a matrix A"
    if backend.needs_axis_name:
        assert axis_name is not None, f"{strategy!r} combine needs axis_name"
    if backend.needs_mesh:
        assert mesh is not None, f"{strategy!r} combine needs a mesh"
    return backend.build(A=A, axis_name=axis_name, mesh=mesh,
                         in_specs=in_specs, block_m=block_m,
                         interpret=interpret, combine_dtype=combine_dtype)


def combine_wire_bytes(A: np.ndarray, strategy: str, model_bytes: int) -> int:
    """Per-step collective-byte model for a backend (benchmark reporting).

    ``model_bytes``: size of one agent's launch model.  dense/pallas gather
    K−1 remote models; sparse (static or dynamic) moves one model per
    offset of the (union) permute schedule; centralized is a
    reduce+broadcast (2·(K−1)/K); none moves nothing.  ``A`` may be a
    ``(K, K)`` matrix or a stacked ``(S, K, K)`` schedule.
    """
    A = np.asarray(A)
    K = A.shape[-1]
    if strategy in ("none",):
        return 0
    if strategy in ("sparse", "sparse_host", "mesh_sparse",
                    "sparse_dynamic", "sparse_host_dynamic",
                    "mesh_sparse_dynamic"):
        return _ir_for(A).degree * model_bytes
    if strategy == "centralized":
        return 2 * (K - 1) * model_bytes // K
    return (K - 1) * model_bytes


# ---------------------------------------------------------------------------
# Diffusion steps
# ---------------------------------------------------------------------------

def atc_step(params: PyTree, updates: PyTree, combine: CombineFn) -> PyTree:
    """Adapt-then-Combine (paper eq. 6a-6b): φ = w + u;  w' = A ⊙ φ."""
    phi = jax.tree.map(lambda p, u: p + u, params, updates)
    return combine(phi)


def cta_step(params: PyTree, updates: PyTree, combine: CombineFn) -> PyTree:
    """Combine-then-Adapt variant (consensus-flavored)."""
    mixed = combine(params)
    return jax.tree.map(lambda p, u: p + u, mixed, updates)


# ---------------------------------------------------------------------------
# Theory metrics
# ---------------------------------------------------------------------------

def centroid(params: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), params)


def disagreement(params: PyTree) -> jax.Array:
    """Network disagreement (Thm 1): (1/K) Σ_k ‖w_k − w_c‖²."""
    leaves = jax.tree.leaves(params)
    K = leaves[0].shape[0]
    total = jnp.zeros((), jnp.float32)
    for x in leaves:
        xc = jnp.mean(x, axis=0, keepdims=True)
        total = total + jnp.sum((x - xc).astype(jnp.float32) ** 2)
    return total / K
