"""Diffusion (Adapt-then-Combine) strategy over a stacked agent axis.

All per-agent launch models are stored with a leading ``K`` (agent) axis on
every parameter leaf.  The combine step (paper eq. 6b)

    w_{k,i} = Σ_l a_{lk} φ_{l,i}

is a contraction over that axis.  Three interchangeable implementations:

``dense_combine``       einsum against the full K×K matrix.  Under pjit with
                        the agent axis sharded over a mesh axis, XLA lowers
                        this to all-gather + local reduction: O(K·|w|)
                        collective bytes.  This is the paper-faithful
                        baseline semantics for arbitrary graphs.
``sparse_combine``      shard_map + lax.ppermute, one collective-permute per
                        circular neighbor offset: O(deg·|w|) bytes.  Exactly
                        equal to dense_combine (assert-tested) whenever A's
                        sparsity is a union of circular offsets (ring, torus
                        on the agent axis, full graph).
``centralized_combine`` every agent receives the centroid (fully-connected
                        uniform A = (1/K)11ᵀ): the paper's centralized
                        reference, an all-reduce.
``no_combine``          identity: the non-cooperative baseline (A = I).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as topo

PyTree = Any
CombineFn = Callable[[PyTree], PyTree]

__all__ = [
    "dense_combine",
    "sparse_combine_host",
    "make_sparse_combine",
    "centralized_combine",
    "no_combine",
    "make_combine",
    "atc_step",
    "cta_step",
    "disagreement",
    "centroid",
]


# ---------------------------------------------------------------------------
# Combine implementations
# ---------------------------------------------------------------------------

def dense_combine(A: jax.Array, phi: PyTree) -> PyTree:
    """w_new[k] = Σ_l A[l, k] φ[l] on the leading agent axis of each leaf."""

    def leaf(x):
        return jnp.einsum("lk,l...->k...", A.astype(x.dtype), x)

    return jax.tree.map(leaf, phi)


def sparse_combine_host(A: np.ndarray, phi: PyTree) -> PyTree:
    """Single-host emulation of the ppermute schedule using jnp.roll.

    Used by tests to validate the sparse schedule without a multi-device
    mesh; identical math to :func:`make_sparse_combine`.
    """
    K = A.shape[0]
    offsets = [d for d in range(1, K)
               if any(A[(k - d) % K, k] > 0 for k in range(K))]
    self_w = jnp.asarray(np.diagonal(A).copy())

    def leaf(x):
        shape = (K,) + (1,) * (x.ndim - 1)
        acc = x * self_w.astype(x.dtype).reshape(shape)
        for d in offsets:
            w_d = jnp.asarray(
                np.array([A[(k - d) % K, k] for k in range(K)]), dtype=x.dtype
            ).reshape(shape)
            # agent k receives from agent (k - d) mod K  ==  roll by +d
            acc = acc + w_d * jnp.roll(x, d, axis=0)
        return acc

    return jax.tree.map(leaf, phi)


def make_sparse_combine(A: np.ndarray, axis_name: str) -> CombineFn:
    """Collective-permute combine, to be called *inside* shard_map where the
    leading agent axis is sharded one-agent-per-shard over ``axis_name``.

    Each circular offset ``d`` with any nonzero weight contributes one
    ``lax.ppermute`` (collective-permute over ICI) plus a per-destination
    weight multiply.  Self weights are a local scale.  Total collective
    bytes = (#offsets) · |w| vs. (K-1)/K · K · |w| for the all-gather that
    XLA emits for the dense einsum.
    """
    K = A.shape[0]
    offsets = [d for d in range(1, K)
               if any(A[(k - d) % K, k] > 0 for k in range(K))]
    self_w = np.diagonal(A).copy()
    off_w = {d: np.array([A[(k - d) % K, k] for k in range(K)]) for d in offsets}

    def combine(phi: PyTree) -> PyTree:
        k = jax.lax.axis_index(axis_name)

        def leaf(x):
            # x: local block (1, ...) — one agent per shard.
            acc = x * jnp.asarray(self_w, x.dtype)[k]
            for d in offsets:
                perm = [(l, (l + d) % K) for l in range(K)]
                recv = jax.lax.ppermute(x, axis_name, perm)
                acc = acc + recv * jnp.asarray(off_w[d], x.dtype)[k]
            return acc

        return jax.tree.map(leaf, phi)

    return combine


def make_mesh_sparse_combine(A: np.ndarray, mesh, axis_name: str,
                             in_specs: PyTree | None = None) -> CombineFn:
    """Production sparse combine: shard_map over the agent mesh axis with the
    ppermute schedule of :func:`make_sparse_combine`.  The agent axis is
    manual; all other axes (e.g. 'model' tensor parallelism) stay auto.

    ``in_specs``: pytree of PartitionSpecs matching phi's *actual* shardings
    (agent dim on ``axis_name`` plus whatever TP axes each leaf carries).
    Omitting the TP axes would make shard_map all-gather every TP-sharded
    parameter at entry — measured +77% step wire bytes on qwen2-1.5b — so
    callers must pass the real specs for TP-sharded trees.

    Wire bytes per device for the exchange itself: (#circular offsets) ×
    |w_local|, vs. (K−1)/K × K × |w_local| for the dense-einsum all-gather."""
    import jax as _jax
    from jax.sharding import PartitionSpec as _P

    inner = make_sparse_combine(A, axis_name)
    specs = in_specs if in_specs is not None else _P(axis_name)

    def combine(phi: PyTree) -> PyTree:
        return _jax.shard_map(
            inner, mesh=mesh, in_specs=specs, out_specs=specs,
            axis_names={axis_name}, check_vma=False)(phi)

    return combine


def centralized_combine(phi: PyTree) -> PyTree:
    """All agents receive the network centroid: A = (1/K) 1 1ᵀ."""

    def leaf(x):
        return jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), x.shape)

    return jax.tree.map(leaf, phi)


def no_combine(phi: PyTree) -> PyTree:
    return phi


def make_combine(strategy: str, A: np.ndarray | None = None,
                 axis_name: str | None = None) -> CombineFn:
    """Factory: 'dense' | 'sparse' | 'sparse_host' | 'centralized' | 'none'."""
    if strategy == "dense":
        assert A is not None
        Aj = jnp.asarray(A)
        return functools.partial(dense_combine, Aj)
    if strategy == "sparse":
        assert A is not None and axis_name is not None
        return make_sparse_combine(A, axis_name)
    if strategy == "sparse_host":
        assert A is not None
        return functools.partial(sparse_combine_host, A)
    if strategy == "centralized":
        return centralized_combine
    if strategy == "none":
        return no_combine
    raise ValueError(f"unknown combine strategy {strategy!r}")


# ---------------------------------------------------------------------------
# Diffusion steps
# ---------------------------------------------------------------------------

def atc_step(params: PyTree, updates: PyTree, combine: CombineFn) -> PyTree:
    """Adapt-then-Combine (paper eq. 6a-6b): φ = w + u;  w' = A ⊙ φ."""
    phi = jax.tree.map(lambda p, u: p + u, params, updates)
    return combine(phi)


def cta_step(params: PyTree, updates: PyTree, combine: CombineFn) -> PyTree:
    """Combine-then-Adapt variant (consensus-flavored)."""
    mixed = combine(params)
    return jax.tree.map(lambda p, u: p + u, mixed, updates)


# ---------------------------------------------------------------------------
# Theory metrics
# ---------------------------------------------------------------------------

def centroid(params: PyTree) -> PyTree:
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), params)


def disagreement(params: PyTree) -> jax.Array:
    """Network disagreement (Thm 1): (1/K) Σ_k ‖w_k − w_c‖²."""
    leaves = jax.tree.leaves(params)
    K = leaves[0].shape[0]
    total = jnp.zeros((), jnp.float32)
    for x in leaves:
        xc = jnp.mean(x, axis=0, keepdims=True)
        total = total + jnp.sum((x - xc).astype(jnp.float32) ** 2)
    return total / K
