"""Dif-MAML trainer (paper Algorithm 1).

State layout: every parameter leaf carries a leading agent axis of size K.
One trainer step =
  1. per-agent, per-task inner adaptation + meta-gradient (vmap over agents,
     vmap over tasks — core/maml.py),
  2. per-agent outer optimizer update  →  intermediate states φ_k,
  3. diffusion combine over the agent axis (core/diffusion.py).

The same trainer expresses the paper's three strategies:
  Dif-MAML        combine='dense'/'sparse' with a graph combination matrix
  centralized     num_agents=1 (all tasks through one agent)  — or
                  combine='centralized' (equivalent to fully-connected A)
  non-cooperative combine='none' (A = I)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diffusion, maml, topology
from repro.optim import Optimizer, clip_by_global_norm, get_optimizer

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]

__all__ = ["MetaConfig", "TrainState", "init_state", "make_meta_step",
           "make_eval_fn", "combination_matrix_for"]


@dataclasses.dataclass(frozen=True)
class MetaConfig:
    num_agents: int = 6
    tasks_per_agent: int = 4          # |S_k|
    inner_lr: float = 0.01            # α
    inner_steps: int = 1
    mode: str = "maml"                # maml | fomaml | reptile
    combine: str = "dense"            # 'auto' | any diffusion.combine_backends() name
    topology: str = "paper"           # ring | grid | torus | full | star | erdos | paper
    comb_rule: str = "metropolis"
    outer_optimizer: str = "adam"
    outer_lr: float = 1e-3            # μ
    grad_clip: float | None = None
    combine_every: int = 1            # communicate every n-th step (beyond-paper knob)
    hvp_subsample: float = 1.0        # curvature-term batch fraction (beyond-paper)


class TrainState(NamedTuple):
    step: jax.Array
    params: PyTree       # leading agent axis K on every leaf
    opt_state: PyTree    # per-agent moments (same leading axis)


def combination_matrix_for(cfg: MetaConfig) -> np.ndarray:
    if cfg.num_agents == 1:
        return np.ones((1, 1))
    return topology.combination_matrix(cfg.num_agents, cfg.topology, cfg.comb_rule)


def init_state(
    rng: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    cfg: MetaConfig,
    optimizer: Optimizer | None = None,
    identical_init: bool = False,
) -> TrainState:
    """Stack K independently-initialized launch models (paper: "Initialize
    the launch models {w_{k,0}}")."""
    opt = optimizer or get_optimizer(cfg.outer_optimizer, cfg.outer_lr)
    if identical_init:
        p0 = init_fn(rng)
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.num_agents,) + x.shape), p0)
    else:
        keys = jax.random.split(rng, cfg.num_agents)
        params = jax.vmap(init_fn)(keys)
    opt_state = opt.init(params)
    return TrainState(jnp.zeros((), jnp.int32), params, opt_state)


def make_meta_step(
    loss_fn: LossFn,
    cfg: MetaConfig,
    optimizer: Optimizer | None = None,
    A: np.ndarray | None = None,
    combine_fn: Callable[[PyTree], PyTree] | None = None,
    freeze_mask: PyTree | None = None,
):
    """Returns ``step(state, support, query) -> (state, metrics)``.

    ``support``/``query``: pytrees of arrays with leading axes
    ``(K, tasks_per_agent, task_batch, ...)``.

    ``combine_fn`` overrides the combine — mesh-aware backends need the
    leaf PartitionSpecs only the launch layer knows, so launch/steps.py
    builds them via ``diffusion.make_combine`` and injects them here.
    """
    opt = optimizer or get_optimizer(cfg.outer_optimizer, cfg.outer_lr)
    if A is None:
        A = combination_matrix_for(cfg)
    if combine_fn is None:
        strategy = cfg.combine if cfg.num_agents > 1 else "none"
        if strategy in ("sparse", "mesh_sparse"):
            # host-level default; mesh version injected by launch/
            strategy = "sparse_host"
        combine_fn = diffusion.make_combine(strategy, A=A)

    def per_agent(params_k, support_k, query_k):
        return maml.multi_task_meta_grad(
            loss_fn, params_k, support_k, query_k,
            alpha=cfg.inner_lr, steps=cfg.inner_steps, mode=cfg.mode,
            hvp_subsample=cfg.hvp_subsample, freeze_mask=freeze_mask)

    def step(state: TrainState, support: Any, query: Any):
        losses, grads = jax.vmap(per_agent)(state.params, support, query)
        if cfg.grad_clip is not None:   # 0.0 is a valid (total) clip
            grads = jax.vmap(lambda g: clip_by_global_norm(g, cfg.grad_clip))(grads)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        if cfg.combine_every > 1:
            do_combine = (state.step % cfg.combine_every) == cfg.combine_every - 1
            phi = jax.tree.map(lambda p, u: p + u, state.params, updates)
            params = jax.tree.map(
                lambda c, p: jnp.where(do_combine, c, p), combine_fn(phi), phi)
        else:
            params = diffusion.atc_step(state.params, updates, combine_fn)
        metrics = {
            "loss": jnp.mean(losses),
            "per_agent_loss": losses,
            "disagreement": diffusion.disagreement(params),
        }
        return TrainState(state.step + 1, params, opt_state), metrics

    return step


def make_eval_fn(loss_fn: LossFn, inner_lr: float, inner_steps: int = 1):
    """Compatibility wrapper over :class:`repro.eval.EvalHarness`.

    Returns ``evaluate(params, support, query) -> (tasks, steps+1)``:
    adapt one launch model on each eval task's support set and report the
    query loss after *each* inner step (index 0 = zero-shot), exactly
    :meth:`EvalHarness.curves`.  New code should build the harness
    directly — it adds the recurring-vs-unseen split protocol, per-agent
    curves, and the generalization-gap report."""
    from repro.eval.harness import EvalHarness
    return EvalHarness(loss_fn, inner_lr=inner_lr,
                       inner_steps=inner_steps).curves
