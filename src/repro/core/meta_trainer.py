"""Decentralized meta-trainer: InnerAlgo × DiffusionStrategy × CommSchedule.

State layout: every parameter leaf carries a leading agent axis of size K.
One trainer step assembles three independently pluggable factors:

  1. **InnerAlgo** (``core/maml.py`` via the ``core/update.py`` registry):
     per-agent, per-task inner adaptation + meta-gradient (vmap over
     agents, vmap over tasks) — ``maml | fomaml | reptile``.
  2. **DiffusionStrategy** (``core/update.py``): how the per-agent outer
     update composes with the combine —
     ``atc | cta | consensus | none | centralized``.
  3. **CommSchedule** × **TopologySchedule**: *when* agents communicate
     (``combine_every``, gated by ``lax.cond`` so skipped steps move no
     bytes) and *over which graph* at each step
     (``static | link_failure | gossip | round_robin`` —
     ``core/topology.py``).

Strategy matrix — which combinations reproduce which baseline:

  =============  ==========  ============  ==============================
  strategy       inner       schedule      reproduces
  =============  ==========  ============  ==============================
  atc            maml        static        Dif-MAML (paper Algorithm 1)
  none           maml        --            non-cooperative baseline
                                           (paper Fig. 2b/3, A = I)
  centralized    maml        --            centralized MAML reference
                                           (paper Fig. 2b/3; equals the
                                           full-graph uniform A exactly)
  atc            fomaml      static        first-order Dif-MAML (Nichol
                                           et al. 2018 inner algo)
  cta            maml        static        combine-then-adapt diffusion
                                           (Sayed 2014; gradient at the
                                           mixed iterate)
  consensus      maml        static        consensus/DGD composition
                                           (gradient at own iterate)
  atc            maml        link_failure  Dif-MAML under i.i.d. edge
                                           drops (beyond-paper)
  atc            maml        gossip        randomized pairwise gossip
                                           (Boyd et al. 2006 flavor)
  =============  ==========  ============  ==============================

Configuration is nested: :class:`TopologyConfig` (who/when graph-wise) and
:class:`UpdateConfig` (strategy/inner/backend/cadence) inside
:class:`MetaConfig`.  The legacy flat fields (``mode``, ``combine``,
``topology``, ``comb_rule``, ``combine_every``) still construct and train
but are deprecated aliases — they emit a ``DeprecationWarning`` pointing at
the nested configs, and the nested configs win when both are given.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diffusion, maml, topology, update
from repro.optim import Optimizer, clip_by_global_norm, get_optimizer

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]

__all__ = ["TopologyConfig", "UpdateConfig", "MetaConfig", "TrainState",
           "init_state", "make_meta_step", "make_eval_fn",
           "topology_for", "schedule_for", "combination_matrix_for",
           "strategy_for_combine"]


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """Who mixes with whom: the graph family, the weight rule, and the
    per-step schedule (:data:`repro.core.topology.SCHEDULES`)."""

    graph: str = "paper"              # ring | grid | torus | full | star | erdos | paper
    rule: str = "metropolis"          # metropolis | uniform
    schedule: str = "static"          # static | link_failure | gossip | round_robin
    link_failure_p: float = 0.2       # per-edge i.i.d. drop prob (link_failure)
    period: int = 64                  # pre-sampled steps for random schedules
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class UpdateConfig:
    """How and when the outer update composes with communication."""

    strategy: str = "atc"             # update.update_strategies() name
    inner: str = "maml"               # update.inner_algos() name
    backend: str = "dense"            # 'auto' | diffusion.combine_backends() name
    combine_every: int = 1            # CommSchedule cadence


# Deprecated flat aliases and the defaults that detect explicit use.
_FLAT_DEFAULTS = {"mode": "maml", "combine": "dense", "topology": "paper",
                  "comb_rule": "metropolis", "combine_every": 1}


def _mirror(tc: "TopologyConfig", uc: "UpdateConfig") -> dict:
    """The flat-alias values implied by the nested configs — what legacy
    readers of ``mode``/``combine``/... see."""
    return {
        "mode": uc.inner,
        "combine": (uc.strategy if uc.strategy in ("none", "centralized")
                    else uc.backend),
        "topology": tc.graph,
        "comb_rule": tc.rule,
        "combine_every": uc.combine_every,
    }


def strategy_for_combine(combine: str, default: str = "atc") -> str:
    """Map a legacy flat ``combine`` name to the strategy it implied:
    'none'/'centralized' were strategies masquerading as backends; every
    real backend name meant plain ATC.  The single owner of this mapping —
    MetaConfig's alias resolution and launch's ``--combine`` override both
    route through here."""
    return {"none": "none", "centralized": "centralized"}.get(combine,
                                                              default)


@dataclasses.dataclass(frozen=True)
class MetaConfig:
    num_agents: int = 6
    tasks_per_agent: int = 4          # |S_k|
    inner_lr: float = 0.01            # α
    inner_steps: int = 1
    outer_optimizer: str = "adam"
    outer_lr: float = 1e-3            # μ
    grad_clip: float | None = None
    hvp_subsample: float = 1.0        # curvature-term batch fraction (beyond-paper)

    # -- the composition axes (preferred surface) ---------------------------
    topology_config: TopologyConfig | None = None
    update_config: UpdateConfig | None = None

    # -- deprecated flat aliases (kept so existing call sites construct) ----
    mode: str = "maml"                # -> update_config.inner
    combine: str = "dense"            # -> update_config.{strategy,backend}
    topology: str = "paper"           # -> topology_config.graph
    comb_rule: str = "metropolis"     # -> topology_config.rule
    combine_every: int = 1            # -> update_config.combine_every

    def __post_init__(self):
        tc, uc = self.topology_config, self.update_config
        if tc is None or uc is None:
            used = [f for f in _FLAT_DEFAULTS
                    if getattr(self, f) != _FLAT_DEFAULTS[f]]
            if used:
                warnings.warn(
                    f"MetaConfig flat field(s) {used} are deprecated "
                    f"aliases; build the nested configs instead — "
                    f"MetaConfig(update_config=UpdateConfig(strategy=..., "
                    f"inner=..., backend=..., combine_every=...), "
                    f"topology_config=TopologyConfig(graph=..., rule=..., "
                    f"schedule=...))",
                    DeprecationWarning, stacklevel=3)
            if uc is None:
                uc = UpdateConfig(strategy=strategy_for_combine(self.combine),
                                  inner=self.mode,
                                  backend=self.combine,
                                  combine_every=self.combine_every)
            if tc is None:
                tc = TopologyConfig(graph=self.topology, rule=self.comb_rule)
            object.__setattr__(self, "topology_config", tc)
            object.__setattr__(self, "update_config", uc)
        else:
            # Both nested configs present (direct nested construction, or a
            # dataclasses.replace carrying them over): the nested configs
            # are the source of truth, so any flat value disagreeing with
            # their mirror is about to be discarded — e.g.
            # ``dataclasses.replace(cfg, mode='fomaml')`` on a config whose
            # nested update_config still says 'maml'.  Silent discard broke
            # the flat-alias contract, so say it out loud.
            ignored = [f for f in _FLAT_DEFAULTS
                       if getattr(self, f) != _mirror(tc, uc)[f]
                       and getattr(self, f) != _FLAT_DEFAULTS[f]]
            if ignored:
                warnings.warn(
                    f"MetaConfig flat field(s) {ignored} conflict with the "
                    f"nested topology_config/update_config and are ignored "
                    f"(the nested configs win). To change these via "
                    f"dataclasses.replace, replace the nested config, e.g. "
                    f"replace(cfg, update_config=dataclasses.replace("
                    f"cfg.update_config, inner=...))",
                    DeprecationWarning, stacklevel=3)
        # Mirror nested -> flat so legacy readers keep seeing the truth.
        for field, value in _mirror(tc, uc).items():
            object.__setattr__(self, field, value)


class TrainState(NamedTuple):
    step: jax.Array
    params: PyTree       # leading agent axis K on every leaf
    opt_state: PyTree    # per-agent moments (same leading axis)


def topology_for(cfg: MetaConfig) -> topology.Topology:
    """The validated :class:`~repro.core.topology.Topology` instance —
    fixed-size graphs (``paper``) reject a mismatched ``num_agents`` here
    with both numbers, before any array work."""
    tc = cfg.topology_config
    return topology.build_topology(tc.graph, cfg.num_agents, tc.rule)


def schedule_for(cfg: MetaConfig) -> topology.TopologySchedule:
    """The per-step combination-matrix schedule the trainer runs on."""
    tc = cfg.topology_config
    kw = {}
    if tc.schedule == "link_failure":
        kw = dict(p=tc.link_failure_p, period=tc.period, seed=tc.seed)
    elif tc.schedule == "gossip":
        kw = dict(period=tc.period, seed=tc.seed)
    return topology.make_schedule(tc.schedule, topology_for(cfg), **kw)


def combination_matrix_for(cfg: MetaConfig) -> np.ndarray:
    """The static ``(K, K)`` matrix (schedule-independent legacy surface)."""
    if cfg.num_agents == 1:
        return np.ones((1, 1))
    return topology_for(cfg).matrix


def init_state(
    rng: jax.Array,
    init_fn: Callable[[jax.Array], PyTree],
    cfg: MetaConfig,
    optimizer: Optimizer | None = None,
    identical_init: bool = False,
) -> TrainState:
    """Stack K independently-initialized launch models (paper: "Initialize
    the launch models {w_{k,0}}")."""
    opt = optimizer or get_optimizer(cfg.outer_optimizer, cfg.outer_lr)
    if identical_init:
        p0 = init_fn(rng)
        params = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.num_agents,) + x.shape), p0)
    else:
        keys = jax.random.split(rng, cfg.num_agents)
        params = jax.vmap(init_fn)(keys)
    opt_state = opt.init(params)
    return TrainState(jnp.zeros((), jnp.int32), params, opt_state)


def make_meta_step(
    loss_fn: LossFn,
    cfg: MetaConfig,
    optimizer: Optimizer | None = None,
    A: np.ndarray | None = None,
    combine_fn: diffusion.CombineFn | None = None,
    freeze_mask: PyTree | None = None,
):
    """Returns ``step(state, support, query) -> (state, metrics)``:
    the InnerAlgo × DiffusionStrategy × CommSchedule assembly.

    ``support``/``query``: pytrees of arrays with leading axes
    ``(K, tasks_per_agent, task_batch, ...)``.

    ``A`` may be one ``(K, K)`` matrix or a stacked ``(S, K, K)`` schedule;
    when omitted it is derived from ``cfg.topology_config`` via
    :func:`schedule_for`.  ``combine_fn`` overrides the combine — mesh-aware
    backends need the leaf PartitionSpecs only the launch layer knows, so
    launch/steps.py builds them via ``diffusion.make_combine`` and injects
    them here (signature ``combine(phi, step)``).

    With ``combine_every > 1`` the communication is gated by ``lax.cond``:
    skipped steps execute no combine matmul/collective at all (the old
    ``jnp.where`` path ran the full combine every step and discarded it).
    """
    opt = optimizer or get_optimizer(cfg.outer_optimizer, cfg.outer_lr)
    uc = cfg.update_config
    strategy_name = uc.strategy if cfg.num_agents > 1 else "none"
    strategy = update.get_strategy(strategy_name)
    algo = update.get_inner_algo(uc.inner)
    comm = update.CommSchedule(uc.combine_every)
    fused_outer = None
    if uc.backend == "fused":
        # one-pass combine-then-update: clip scale, moments, launch-model
        # mix all happen inside a single kernel sweep over the param bytes
        from repro.core.fused import make_fused_outer
        if A is None and strategy.needs_combine_fn:
            A = schedule_for(cfg).stacked()
        fused_outer = make_fused_outer(
            opt, strategy_name, comm, A, grad_clip=cfg.grad_clip,
            num_agents=cfg.num_agents)
    if (combine_fn is None and strategy.needs_combine_fn
            and (fused_outer is None or strategy.pre_combine)):
        if A is None:
            A = schedule_for(cfg).stacked()
        backend = uc.backend
        if backend in ("sparse", "mesh_sparse"):
            # host-level default; mesh version injected by launch/
            backend = "sparse_host"
        backend = diffusion.resolve_schedule_backend(backend, A)
        combine_fn = diffusion.make_combine(backend, A=A)

    def per_agent(params_k, support_k, query_k):
        return maml.multi_task_meta_grad(
            loss_fn, params_k, support_k, query_k,
            alpha=cfg.inner_lr, steps=cfg.inner_steps, mode=algo.mode,
            hvp_subsample=cfg.hvp_subsample, freeze_mask=freeze_mask)

    # lax.cond gating only matters when the strategy actually communicates
    gated = strategy.communicates and not comm.always

    def step(state: TrainState, support: Any, query: Any):
        idx = state.step
        base = state.params
        if strategy.pre_combine:
            mix = lambda p: combine_fn(p, idx)
            base = (jax.lax.cond(comm.is_comm_step(idx), mix, lambda p: p,
                                 base)
                    if gated else mix(base))
        losses, grads = jax.vmap(per_agent)(base, support, query)
        if fused_outer is not None:
            # no lax.cond: skipped comm steps must still advance the
            # moments, and the kernel's gate blends the mix to identity
            params, opt_state = fused_outer(base, grads, state.opt_state,
                                            idx)
        else:
            if cfg.grad_clip is not None:   # 0.0 is a valid (total) clip
                grads = jax.vmap(lambda g: clip_by_global_norm(g, cfg.grad_clip))(grads)
            updates, opt_state = opt.update(grads, state.opt_state, base)
            if gated and not strategy.pre_combine:
                params = jax.lax.cond(
                    comm.is_comm_step(idx),
                    lambda p, u: strategy.apply(p, u, combine_fn, idx),
                    update.local_update, base, updates)
            else:
                params = strategy.apply(base, updates, combine_fn, idx)
        metrics = {
            "loss": jnp.mean(losses),
            "per_agent_loss": losses,
            "disagreement": diffusion.disagreement(params),
        }
        return TrainState(state.step + 1, params, opt_state), metrics

    return step


def make_eval_fn(loss_fn: LossFn, inner_lr: float, inner_steps: int = 1):
    """Compatibility wrapper over :class:`repro.eval.EvalHarness`.

    Returns ``evaluate(params, support, query) -> (tasks, steps+1)``:
    adapt one launch model on each eval task's support set and report the
    query loss after *each* inner step (index 0 = zero-shot), exactly
    :meth:`EvalHarness.curves`.  New code should build the harness
    directly — it adds the recurring-vs-unseen split protocol, per-agent
    curves, and the generalization-gap report."""
    from repro.eval.harness import EvalHarness
    return EvalHarness(loss_fn, inner_lr=inner_lr,
                       inner_steps=inner_steps).curves
