"""MAML inner/outer loops (paper §1.1, eq. 2-4).

Generic over the model: a ``loss_fn(params, batch) -> scalar`` closure.  The
exact meta-gradient (eq. 4) — including the ``(I - α ∇²Q)`` curvature factor —
falls out of differentiating through the inner SGD step with ``jax.grad``;
no Hessian is ever materialized (JAX computes the Hessian-vector product).

Three modes:
  'maml'    exact second-order meta-gradient (paper's algorithm)
  'fomaml'  first-order: curvature term dropped via stop_gradient on the
            inner gradient (Nichol et al. 2018; used for frontier-scale archs)
  'reptile' update direction = (w_adapted - w); no outer batch needed
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]

__all__ = ["inner_adapt", "meta_loss", "meta_grad", "multi_task_meta_grad"]


def _sgd_step(params: PyTree, grads: PyTree, alpha: float) -> PyTree:
    return jax.tree.map(lambda p, g: p - alpha * g, params, grads)


def inner_adapt(
    loss_fn: LossFn,
    params: PyTree,
    batch: Any,
    alpha: float,
    steps: int = 1,
    first_order: bool = False,
    remat: bool = True,
) -> PyTree:
    """Task adaptation: ``w' = w - α ∇Q(w; X_in)`` applied ``steps`` times.

    With ``first_order=True`` the inner gradient is treated as a constant of
    the outer differentiation (FOMAML).

    ``remat=True`` wraps each inner step in ``jax.checkpoint``: the exact
    (second-order) meta-gradient differentiates *through* the inner backward
    pass, and without remat XLA must keep every layer's inner-backward
    intermediates alive until the outer backward — O(L·S·d) extra residency
    that dominated HBM in the 4k-seq dry-runs.  With remat, the outer
    backward recomputes the inner fwd+bwd transiently (one extra fwd+bwd of
    compute, ~500× less attention residency at 28 layers × 8 chunks).
    """

    def step_fn(p):
        g = jax.grad(loss_fn)(p, batch)
        if first_order:
            g = jax.lax.stop_gradient(g)
        return _sgd_step(p, g, alpha)

    if remat and not first_order:
        step_fn = jax.checkpoint(step_fn)

    def one_step(p, _):
        return step_fn(p), None

    if steps == 1:  # common case; keep the HLO flat
        return step_fn(params)
    adapted, _ = jax.lax.scan(one_step, params, None, length=steps)
    return adapted


def meta_loss(
    loss_fn: LossFn,
    params: PyTree,
    support: Any,
    query: Any,
    alpha: float,
    steps: int = 1,
    mode: str = "maml",
) -> jax.Array:
    """Meta objective for a single task: ``Q(w - α∇Q(w; X_in); X_o)``."""
    if mode == "reptile":
        # Reptile has no outer loss; callers use meta_grad directly.
        adapted = inner_adapt(loss_fn, params, support, alpha, steps, first_order=True)
        return loss_fn(adapted, query)
    first_order = mode == "fomaml"
    adapted = inner_adapt(loss_fn, params, support, alpha, steps, first_order=first_order)
    return loss_fn(adapted, query)


def meta_grad(
    loss_fn: LossFn,
    params: PyTree,
    support: Any,
    query: Any,
    alpha: float,
    steps: int = 1,
    mode: str = "maml",
    hvp_subsample: float = 1.0,
    freeze_mask: PyTree | None = None,
) -> tuple[jax.Array, PyTree]:
    """Stochastic meta-gradient ``∇Q̄`` for one task (eq. 4).  Returns
    (outer loss value, meta-gradient pytree).

    mode='maml' computes the exact second-order gradient

        ∇Q̄ = ∏_j (I − α ∇²Q_in(w_j)) · ∇Q_o(w')

    with the curvature factors applied as Hessian-vector products in
    **forward-over-reverse** form, ``jvp(grad(Q_in), (w_j,), (v,))``.
    Reverse-over-reverse (plain ``grad`` through the inner update) forces
    XLA to keep the inner backward's per-layer residuals alive until the
    outer backward — O(L · S² · heads) bytes at 4k sequence — whereas
    forward-mode tangents stream alongside the recomputed inner backward
    with O(1) extra residency.  Same math (tested against the naive form
    and the analytic quadratic), production memory behavior.

    mode='maml_naive' keeps the differentiate-through-the-update form for
    cross-validation on small models.
    """
    if mode == "reptile":
        adapted = inner_adapt(loss_fn, params, support, alpha, steps, first_order=True)
        # Direction (w - w') / α plays the role of the meta-gradient.
        g = jax.tree.map(lambda p, a: (p - a) / max(alpha, 1e-12), params, adapted)
        return loss_fn(adapted, query), g
    if freeze_mask is not None:
        # ANIL-style partial adaptation (Raghu et al. 2020, cited by the
        # paper): frozen leaves are stop-gradiented inside the *inner* loss,
        # so the inner gradient, the inner update, and the curvature
        # cross-terms vanish on them exactly; the outer gradient still
        # trains them.  Used for modality frontends (whisper encoder).
        def _mix(p):
            return jax.tree.map(
                lambda leaf, frozen: jax.lax.stop_gradient(leaf) if frozen
                else leaf, p, freeze_mask)
        inner_loss = lambda p, b: loss_fn(_mix(p), b)
    else:
        inner_loss = loss_fn
    if mode == "maml":
        grad_in = lambda p: jax.grad(inner_loss)(p, support)
        trajectory = []
        p = params
        for _ in range(steps):
            trajectory.append(p)
            p = _sgd_step(p, grad_in(p), alpha)
        loss, v = jax.value_and_grad(loss_fn)(p, query)
        if hvp_subsample < 1.0:
            # beyond-paper knob: estimate ∇²Q_in on a support subsample.
            # The HVP is the most expensive pass of the meta step (measured
            # 59% of compiled FLOPs); a fractional batch keeps the estimator
            # unbiased w.r.t. the adjusted objective at 1/f the cost, at the
            # price of curvature-term variance (validated on the sine bench).
            def sub(x):
                n = max(1, int(x.shape[0] * hvp_subsample))
                return x[:n]
            sub_batch = jax.tree.map(sub, support)
            grad_hvp = lambda p: jax.grad(inner_loss)(p, sub_batch)
        else:
            grad_hvp = grad_in
        for w_j in reversed(trajectory):
            _, hv = jax.jvp(grad_hvp, (w_j,), (v,))    # ∇²Q_in(w_j) · v
            v = jax.tree.map(lambda a, b: a - alpha * b, v, hv)
        return loss, v
    # fomaml / maml_naive: adapt with the (possibly masked) inner loss, take
    # the outer loss unmasked so frozen leaves still receive meta-gradients
    first_order = mode == "fomaml"

    def full(p):
        adapted = inner_adapt(inner_loss, p, support, alpha, steps,
                              first_order=first_order)
        return loss_fn(adapted, query)

    return jax.value_and_grad(full)(params)


def multi_task_meta_grad(
    loss_fn: LossFn,
    params: PyTree,
    support: Any,
    query: Any,
    alpha: float,
    steps: int = 1,
    mode: str = "maml",
    hvp_subsample: float = 1.0,
    freeze_mask: PyTree | None = None,
) -> tuple[jax.Array, PyTree]:
    """Meta-gradient averaged over a batch of tasks (leading axis of
    ``support``/``query`` is the task axis): ``(1/|S_k|) Σ_t ∇Q̄^(t)``."""

    def per_task(s, q):
        return meta_grad(loss_fn, params, s, q, alpha, steps, mode,
                         hvp_subsample, freeze_mask)

    losses, grads = jax.vmap(per_task)(support, query)
    return jnp.mean(losses), jax.tree.map(lambda g: jnp.mean(g, axis=0), grads)
