"""Graph topologies and doubly-stochastic combination matrices.

The combination matrix ``A = [a_{lk}]`` weights how agent ``k`` combines the
intermediate states of its neighbors ``l`` (paper eq. 6b).  Column ``k`` of
``A`` holds agent ``k``'s incoming weights.  Assumption 6 of the paper
requires ``A`` doubly stochastic and primitive; the Metropolis(-Hastings)
rule below satisfies both for any connected undirected graph with at least
one self-loop weight > 0.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "ring_edges",
    "grid_edges",
    "full_edges",
    "star_edges",
    "erdos_edges",
    "paper_fig2a_edges",
    "adjacency",
    "metropolis_weights",
    "uniform_weights",
    "mixing_rate",
    "is_doubly_stochastic",
    "is_primitive",
    "neighbor_lists",
]


# ---------------------------------------------------------------------------
# Edge constructors.  All return a list of undirected edges (l, k), l < k.
# ---------------------------------------------------------------------------

def ring_edges(K: int) -> list[tuple[int, int]]:
    if K < 2:
        return []
    edges = [(i, (i + 1) % K) for i in range(K)]
    return sorted({(min(a, b), max(a, b)) for a, b in edges})


def grid_edges(rows: int, cols: int, torus: bool = False) -> list[tuple[int, int]]:
    """2-D grid (optionally wrapped into a torus)."""
    edges = set()
    for r in range(rows):
        for c in range(cols):
            k = r * cols + c
            if c + 1 < cols:
                edges.add((k, r * cols + c + 1))
            elif torus and cols > 2:
                edges.add((min(k, r * cols), max(k, r * cols)))
            if r + 1 < rows:
                edges.add((k, (r + 1) * cols + c))
            elif torus and rows > 2:
                edges.add((min(k, c), max(k, c)))
    return sorted(edges)


def full_edges(K: int) -> list[tuple[int, int]]:
    return [(l, k) for l in range(K) for k in range(l + 1, K)]


def star_edges(K: int) -> list[tuple[int, int]]:
    return [(0, k) for k in range(1, K)]


def erdos_edges(K: int, p: float = 0.4, seed: int = 0) -> list[tuple[int, int]]:
    """Erdos-Renyi graph, re-sampled until connected."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        mask = rng.random((K, K)) < p
        edges = [(l, k) for l in range(K) for k in range(l + 1, K) if mask[l, k]]
        if _connected(K, edges):
            return edges
    raise RuntimeError("could not sample a connected graph")


def paper_fig2a_edges() -> list[tuple[int, int]]:
    """The K=6 topology of the paper's Fig. 2a (a connected, non-complete
    graph; the paper does not give the exact edge list, we use a 6-node
    graph with the same flavor: a cycle plus two chords)."""
    return [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0), (1, 4), (2, 5)]


TOPOLOGIES = {
    "ring": lambda K, **kw: ring_edges(K),
    "full": lambda K, **kw: full_edges(K),
    "star": lambda K, **kw: star_edges(K),
    "grid": lambda K, **kw: grid_edges(*_factor(K), torus=False),
    "torus": lambda K, **kw: grid_edges(*_factor(K), torus=True),
    "erdos": lambda K, **kw: erdos_edges(K, **kw),
    "paper": lambda K, **kw: paper_fig2a_edges(),
}


def _factor(K: int) -> tuple[int, int]:
    r = int(np.sqrt(K))
    while K % r:
        r -= 1
    return r, K // r


def _connected(K: int, edges) -> bool:
    seen = {0}
    frontier = [0]
    adj = {i: [] for i in range(K)}
    for l, k in edges:
        adj[l].append(k)
        adj[k].append(l)
    while frontier:
        n = frontier.pop()
        for m in adj[n]:
            if m not in seen:
                seen.add(m)
                frontier.append(m)
    return len(seen) == K


# ---------------------------------------------------------------------------
# Combination matrices.
# ---------------------------------------------------------------------------

def adjacency(K: int, edges) -> np.ndarray:
    M = np.zeros((K, K), dtype=np.float64)
    for l, k in edges:
        M[l, k] = M[k, l] = 1.0
    return M


def metropolis_weights(K: int, edges) -> np.ndarray:
    """Metropolis-Hastings rule: a_{lk} = 1 / (1 + max(d_l, d_k)) for an edge,
    self-weight absorbs the remainder.  Symmetric => doubly stochastic."""
    adj = adjacency(K, edges)
    deg = adj.sum(axis=1)
    A = np.zeros((K, K), dtype=np.float64)
    for l, k in edges:
        A[l, k] = A[k, l] = 1.0 / (1.0 + max(deg[l], deg[k]))
    np.fill_diagonal(A, 1.0 - A.sum(axis=1))
    return A


def uniform_weights(K: int, edges) -> np.ndarray:
    """Lazy uniform averaging with max-degree normalization (also doubly
    stochastic for undirected graphs)."""
    adj = adjacency(K, edges)
    dmax = adj.sum(axis=1).max()
    A = adj / (dmax + 1.0)
    np.fill_diagonal(A, 1.0 - A.sum(axis=1))
    return A


def combination_matrix(K: int, topology: str = "ring", rule: str = "metropolis",
                       **kw) -> np.ndarray:
    edges = TOPOLOGIES[topology](K, **kw)
    if K == 1:
        return np.ones((1, 1))
    fn = metropolis_weights if rule == "metropolis" else uniform_weights
    return fn(K, edges)


# ---------------------------------------------------------------------------
# Spectral / validation helpers (theory quantities from §3).
# ---------------------------------------------------------------------------

def mixing_rate(A: np.ndarray) -> float:
    """λ₂ = spectral radius of A^T - (1/K) 1 1^T  (paper Thm 1)."""
    K = A.shape[0]
    B = A.T - np.ones((K, K)) / K
    return float(np.max(np.abs(np.linalg.eigvals(B))))


def is_doubly_stochastic(A: np.ndarray, tol: float = 1e-9) -> bool:
    return (
        bool(np.all(A >= -tol))
        and bool(np.allclose(A.sum(axis=0), 1.0, atol=tol))
        and bool(np.allclose(A.sum(axis=1), 1.0, atol=tol))
    )


def is_primitive(A: np.ndarray) -> bool:
    """Primitive: some power of A is entrywise positive.  For a stochastic A
    it suffices that the graph is connected and at least one self-loop."""
    K = A.shape[0]
    M = (A > 0).astype(np.float64)
    P = np.linalg.matrix_power(M + np.eye(K) * 0, K * K)  # A^(K^2)
    # power of the boolean pattern:
    P = np.linalg.matrix_power(M, max(1, (K - 1) * (K - 1) + 1))
    return bool(np.all(P > 0))


def neighbor_lists(A: np.ndarray) -> list[list[int]]:
    """For each agent k, incoming neighbors l (a_{lk} > 0), excluding self."""
    K = A.shape[0]
    return [[l for l in range(K) if l != k and A[l, k] > 0] for k in range(K)]


def permute_offsets(A: np.ndarray, K: int) -> list[int]:
    """For circulant (ring/torus-on-agent-axis) matrices: the set of nonzero
    offsets d such that a_{(k-d) mod K, k} > 0 for all k.  Used by the sparse
    ppermute combine.  Returns [] if A is not circulant."""
    offsets = []
    for d in range(1, K):
        col = np.array([A[(k - d) % K, k] for k in range(K)])
        if np.all(col > 0):
            offsets.append(d)
        elif np.any(col > 0):
            return []  # not circulant-sparse
    return offsets


def is_circulant(A: np.ndarray, tol: float = 1e-12) -> bool:
    K = A.shape[0]
    first = A[:, 0]
    for k in range(1, K):
        if not np.allclose(np.roll(first, k), A[:, k], atol=tol):
            return False
    return True
