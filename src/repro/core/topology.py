"""Graph topologies, doubly-stochastic combination matrices, and
per-step communication-graph schedules.

The combination matrix ``A = [a_{lk}]`` weights how agent ``k`` combines the
intermediate states of its neighbors ``l`` (paper eq. 6b).  Column ``k`` of
``A`` holds agent ``k``'s incoming weights.  Assumption 6 of the paper
requires ``A`` doubly stochastic and primitive; the Metropolis(-Hastings)
rule below satisfies both for any connected undirected graph with at least
one self-loop weight > 0.

Two object layers sit on top of the raw edge/matrix helpers:

:class:`Topology`
    one named graph instance — K, the edge set, the combination rule, the
    matrix, and the spectral diagnostics (``mixing_rate``, connectivity,
    double stochasticity) Thm 1 reasons about.

:class:`TopologySchedule`
    *who mixes with whom at step i*: a stacked ``(S, K, K)`` array of
    per-step combination matrices, cycled with period ``S``.  The stack is
    precomputed on the host so dynamic graphs stay jit-compatible — the
    combine backend indexes the stack with the traced step counter instead
    of re-tracing per graph.  ``ir()`` additionally emits the sparse
    :class:`ScheduleIR` lowering (the union of circular offsets over the
    period plus per-step weight tables) that the ``*_dynamic`` combine
    backends turn into a fixed set of ``lax.ppermute`` rounds at
    O(deg·|w|) wire cost.  Kinds (:data:`SCHEDULES`):

    ``static``        every step uses the topology's matrix (S = 1)
    ``link_failure``  each edge drops i.i.d. with probability ``p`` per
                      step; weights are re-derived on the surviving
                      subgraph, so every per-step matrix stays doubly
                      stochastic (a pre-sampled period of ``period`` draws
                      is cycled)
    ``gossip``        randomized gossip: one uniformly-drawn edge per step
                      performs a pairwise half-half exchange, everyone
                      else holds (Boyd et al. 2006 flavor)
    ``round_robin``   deterministic matchings: the edge set is greedily
                      colored so no two edges in a round share an agent;
                      round ``i mod S`` activates one matching, covering
                      every edge once per period
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

__all__ = [
    "ring_edges",
    "grid_edges",
    "full_edges",
    "star_edges",
    "erdos_edges",
    "paper_fig2a_edges",
    "adjacency",
    "metropolis_weights",
    "uniform_weights",
    "mixing_rate",
    "is_doubly_stochastic",
    "is_primitive",
    "neighbor_lists",
    "Topology",
    "build_topology",
    "ScheduleIR",
    "schedule_ir",
    "TopologySchedule",
    "make_schedule",
    "SCHEDULES",
    "FIXED_SIZE",
]


# ---------------------------------------------------------------------------
# Edge constructors.  All return a list of undirected edges (l, k), l < k.
# ---------------------------------------------------------------------------

def ring_edges(K: int) -> list[tuple[int, int]]:
    if K < 2:
        return []
    edges = [(i, (i + 1) % K) for i in range(K)]
    return sorted({(min(a, b), max(a, b)) for a, b in edges})


def grid_edges(rows: int, cols: int, torus: bool = False) -> list[tuple[int, int]]:
    """2-D grid (optionally wrapped into a torus)."""
    edges = set()
    for r in range(rows):
        for c in range(cols):
            k = r * cols + c
            if c + 1 < cols:
                edges.add((k, r * cols + c + 1))
            elif torus and cols > 2:
                edges.add((min(k, r * cols), max(k, r * cols)))
            if r + 1 < rows:
                edges.add((k, (r + 1) * cols + c))
            elif torus and rows > 2:
                edges.add((min(k, c), max(k, c)))
    return sorted(edges)


def full_edges(K: int) -> list[tuple[int, int]]:
    return [(l, k) for l in range(K) for k in range(l + 1, K)]


def star_edges(K: int) -> list[tuple[int, int]]:
    return [(0, k) for k in range(1, K)]


def erdos_edges(K: int, p: float = 0.4, seed: int = 0) -> list[tuple[int, int]]:
    """Erdos-Renyi graph, re-sampled until connected."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        mask = rng.random((K, K)) < p
        edges = [(l, k) for l in range(K) for k in range(l + 1, K) if mask[l, k]]
        if _connected(K, edges):
            return edges
    raise RuntimeError("could not sample a connected graph")


def paper_fig2a_edges() -> list[tuple[int, int]]:
    """The K=6 topology of the paper's Fig. 2a (a connected, non-complete
    graph; the paper does not give the exact edge list, we use a 6-node
    graph with the same flavor: a cycle plus two chords)."""
    return [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0), (1, 4), (2, 5)]


TOPOLOGIES = {
    "ring": lambda K, **kw: ring_edges(K),
    "full": lambda K, **kw: full_edges(K),
    "star": lambda K, **kw: star_edges(K),
    "grid": lambda K, **kw: grid_edges(*_factor(K), torus=False),
    "torus": lambda K, **kw: grid_edges(*_factor(K), torus=True),
    "erdos": lambda K, **kw: erdos_edges(K, **kw),
    "paper": lambda K, **kw: paper_fig2a_edges(),
}

# Graphs with a hard-wired agent count: requesting any other K would either
# index out of range or silently leave isolated agents, so edge construction
# validates eagerly (see ``_edges_for``).
FIXED_SIZE = {"paper": 6}


def _check_name(topology: str) -> None:
    if topology not in TOPOLOGIES:
        raise ValueError(f"unknown topology {topology!r}; "
                         f"available: {tuple(TOPOLOGIES)}")


def _edges_for(K: int, topology: str, **kw) -> list[tuple[int, int]]:
    _check_name(topology)
    fixed = FIXED_SIZE.get(topology)
    if fixed is not None and K != fixed:
        raise ValueError(
            f"topology {topology!r} is a fixed {fixed}-agent graph but "
            f"num_agents={K}; run with {fixed} agents or pick a sized "
            f"topology ({tuple(t for t in TOPOLOGIES if t not in FIXED_SIZE)})")
    return TOPOLOGIES[topology](K, **kw)


def _factor(K: int) -> tuple[int, int]:
    r = int(np.sqrt(K))
    while K % r:
        r -= 1
    return r, K // r


def _connected(K: int, edges) -> bool:
    seen = {0}
    frontier = [0]
    adj = {i: [] for i in range(K)}
    for l, k in edges:
        adj[l].append(k)
        adj[k].append(l)
    while frontier:
        n = frontier.pop()
        for m in adj[n]:
            if m not in seen:
                seen.add(m)
                frontier.append(m)
    return len(seen) == K


# ---------------------------------------------------------------------------
# Combination matrices.
# ---------------------------------------------------------------------------

def adjacency(K: int, edges) -> np.ndarray:
    M = np.zeros((K, K), dtype=np.float64)
    for l, k in edges:
        M[l, k] = M[k, l] = 1.0
    return M


def metropolis_weights(K: int, edges) -> np.ndarray:
    """Metropolis-Hastings rule: a_{lk} = 1 / (1 + max(d_l, d_k)) for an edge,
    self-weight absorbs the remainder.  Symmetric => doubly stochastic."""
    adj = adjacency(K, edges)
    deg = adj.sum(axis=1)
    A = np.zeros((K, K), dtype=np.float64)
    for l, k in edges:
        A[l, k] = A[k, l] = 1.0 / (1.0 + max(deg[l], deg[k]))
    np.fill_diagonal(A, 1.0 - A.sum(axis=1))
    return A


def uniform_weights(K: int, edges) -> np.ndarray:
    """Lazy uniform averaging with max-degree normalization (also doubly
    stochastic for undirected graphs)."""
    adj = adjacency(K, edges)
    dmax = adj.sum(axis=1).max()
    A = adj / (dmax + 1.0)
    np.fill_diagonal(A, 1.0 - A.sum(axis=1))
    return A


def _rule_fn(rule: str):
    if rule == "metropolis":
        return metropolis_weights
    if rule == "uniform":
        return uniform_weights
    raise ValueError(f"unknown combination rule {rule!r}; "
                     f"available: ('metropolis', 'uniform')")


def combination_matrix(K: int, topology: str = "ring", rule: str = "metropolis",
                       **kw) -> np.ndarray:
    fn = _rule_fn(rule)          # validate even on the K=1 degenerate path
    _check_name(topology)        # so a typo never runs green at K=1
    if K == 1:
        return np.ones((1, 1))
    return fn(K, _edges_for(K, topology, **kw))


# ---------------------------------------------------------------------------
# Spectral / validation helpers (theory quantities from §3).
# ---------------------------------------------------------------------------

def mixing_rate(A: np.ndarray) -> float:
    """λ₂ = spectral radius of A^T - (1/K) 1 1^T  (paper Thm 1)."""
    K = A.shape[0]
    B = A.T - np.ones((K, K)) / K
    return float(np.max(np.abs(np.linalg.eigvals(B))))


def is_doubly_stochastic(A: np.ndarray, tol: float = 1e-9) -> bool:
    return (
        bool(np.all(A >= -tol))
        and bool(np.allclose(A.sum(axis=0), 1.0, atol=tol))
        and bool(np.allclose(A.sum(axis=1), 1.0, atol=tol))
    )


def is_primitive(A: np.ndarray) -> bool:
    """Primitive: some power of A is entrywise positive.  For a stochastic A
    it suffices that the graph is connected and at least one self-loop."""
    K = A.shape[0]
    M = (A > 0).astype(np.float64)
    P = np.linalg.matrix_power(M + np.eye(K) * 0, K * K)  # A^(K^2)
    # power of the boolean pattern:
    P = np.linalg.matrix_power(M, max(1, (K - 1) * (K - 1) + 1))
    return bool(np.all(P > 0))


def neighbor_lists(A: np.ndarray) -> list[list[int]]:
    """For each agent k, incoming neighbors l (a_{lk} > 0), excluding self."""
    K = A.shape[0]
    return [[l for l in range(K) if l != k and A[l, k] > 0] for k in range(K)]


def permute_offsets(A: np.ndarray, K: int) -> list[int]:
    """For circulant (ring/torus-on-agent-axis) matrices: the set of nonzero
    offsets d such that a_{(k-d) mod K, k} > 0 for all k.  Used by the sparse
    ppermute combine.  Returns [] if A is not circulant."""
    offsets = []
    for d in range(1, K):
        col = np.array([A[(k - d) % K, k] for k in range(K)])
        if np.all(col > 0):
            offsets.append(d)
        elif np.any(col > 0):
            return []  # not circulant-sparse
    return offsets


def is_circulant(A: np.ndarray, tol: float = 1e-12) -> bool:
    K = A.shape[0]
    first = A[:, 0]
    for k in range(1, K):
        if not np.allclose(np.roll(first, k), A[:, k], atol=tol):
            return False
    return True


# ---------------------------------------------------------------------------
# Topology: one named graph instance with its matrix + diagnostics
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Topology:
    """A named communication graph: K agents, an undirected edge set, and
    the combination rule that turns it into a doubly-stochastic matrix."""

    name: str
    K: int
    edges: tuple[tuple[int, int], ...]
    rule: str = "metropolis"

    @functools.cached_property
    def matrix(self) -> np.ndarray:
        if self.K == 1:
            return np.ones((1, 1))
        return _rule_fn(self.rule)(self.K, list(self.edges))

    @functools.cached_property
    def mixing_rate(self) -> float:
        """λ₂ — the linear agreement rate of Thm 1."""
        return mixing_rate(self.matrix)

    @property
    def connected(self) -> bool:
        return _connected(self.K, list(self.edges))

    @property
    def max_degree(self) -> int:
        deg = np.zeros(self.K, dtype=int)
        for l, k in self.edges:
            deg[l] += 1
            deg[k] += 1
        return int(deg.max()) if self.K else 0

    def diagnostics(self) -> dict:
        """Spectral/structural summary (benchmark + run-log reporting)."""
        A = self.matrix
        return {
            "name": self.name,
            "K": self.K,
            "edges": len(self.edges),
            "rule": self.rule,
            "mixing_rate": self.mixing_rate,
            "doubly_stochastic": is_doubly_stochastic(A),
            "primitive": is_primitive(A),
            "connected": self.connected,
        }


def build_topology(name: str, K: int, rule: str = "metropolis",
                   **kw) -> Topology:
    """Construct a :class:`Topology`, validating K against fixed-size graphs
    eagerly (a 'paper' graph with ``--agents 4`` fails here with both
    numbers, not later with a shape error)."""
    _rule_fn(rule)           # validate the rule name eagerly too
    _check_name(name)
    edges = _edges_for(K, name, **kw) if K > 1 else []
    return Topology(name=name, K=K, edges=tuple(edges), rule=rule)


# ---------------------------------------------------------------------------
# ScheduleIR: sparse lowering of a periodic matrix schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleIR:
    """Structured sparse form of a periodic ``(S, K, K)`` matrix schedule.

    Every off-diagonal entry ``A_s[l, k]`` belongs to exactly one circular
    offset ``d = (k - l) mod K``, so any matrix stack decomposes *exactly*
    into per-offset destination-weight vectors:

      ``offsets``         union over the period of offsets ``d`` carrying
                          any nonzero weight at any step — the fixed
                          ``lax.ppermute`` rounds a dynamic-sparse combine
                          executes (round_robin/link_failure/gossip never
                          activate an edge outside the static graph, so
                          this is the static graph's offset set)
      ``self_weights``    ``(S, K)`` — per-step diagonal of ``A_s``
      ``offset_weights``  ``(S, D, K)`` with ``D = len(offsets)``:
                          ``offset_weights[s, i, k] =
                          A_s[(k - offsets[i]) mod K, k]`` — agent ``k``'s
                          incoming weight over round ``i`` at step ``s``.
                          Steps that do not activate an offset carry
                          elementwise-zero weights (the permute still runs:
                          the round set is step-independent, which is what
                          keeps the lowering jit-compatible)

    The combine backends gather row ``step % S`` of both tables with the
    traced step index, so a dynamic graph costs D collective-permutes of
    one model each — O(deg·|w|) wire — instead of the O(K·|w|) gather of
    the dense step-indexed einsum.
    """

    K: int
    offsets: tuple[int, ...]
    self_weights: np.ndarray      # (S, K)
    offset_weights: np.ndarray    # (S, D, K)

    @property
    def period(self) -> int:
        return self.self_weights.shape[0]

    @property
    def degree(self) -> int:
        """Number of permute rounds D (the wire cost in models/step)."""
        return len(self.offsets)

    def matrix_at(self, step: int) -> np.ndarray:
        """Reconstruct the dense matrix of ``step`` (exact inverse of
        :func:`schedule_ir` — regression surface for the lowering)."""
        s = step % self.period
        A = np.zeros((self.K, self.K), dtype=self.self_weights.dtype)
        np.fill_diagonal(A, self.self_weights[s])
        for i, d in enumerate(self.offsets):
            for k in range(self.K):
                A[(k - d) % self.K, k] = self.offset_weights[s, i, k]
        return A

    def stacked(self) -> np.ndarray:
        return np.stack([self.matrix_at(s) for s in range(self.period)])


def schedule_ir(matrices: np.ndarray) -> ScheduleIR:
    """Lower a ``(K, K)`` matrix or stacked ``(S, K, K)`` schedule to its
    exact :class:`ScheduleIR` decomposition."""
    M = np.asarray(matrices)
    if M.ndim == 2:
        M = M[None]
    S, K, _ = M.shape
    # != 0, not > 0: negative off-diagonal weights (e.g. accelerated
    # consensus matrices) are legal entries and must keep their offset
    offsets = tuple(d for d in range(1, K)
                    if any(M[s, (k - d) % K, k] != 0
                           for s in range(S) for k in range(K)))
    self_w = np.stack([np.diagonal(M[s]).copy() for s in range(S)])
    off_w = np.zeros((S, len(offsets), K), dtype=M.dtype)
    for s in range(S):
        for i, d in enumerate(offsets):
            off_w[s, i] = [M[s, (k - d) % K, k] for k in range(K)]
    return ScheduleIR(K=K, offsets=offsets, self_weights=self_w,
                      offset_weights=off_w)


# ---------------------------------------------------------------------------
# TopologySchedule: who mixes with whom at step i, as a stacked matrix array
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A periodic sequence of combination matrices.

    ``matrices`` is ``(S, K, K)``; step ``i`` uses ``matrices[i % S]``.
    Every entry is doubly stochastic by construction, so the centroid is
    invariant at every step (the Thm 2 mechanism survives dynamic graphs).
    ``stacked()`` feeds :func:`repro.core.diffusion.make_combine` — the
    backend indexes the stack with the traced step counter, keeping dynamic
    graphs inside one jit-compiled step function.
    """

    kind: str
    topology: Topology
    matrices: np.ndarray

    @property
    def period(self) -> int:
        return self.matrices.shape[0]

    @property
    def static(self) -> bool:
        return self.period == 1

    def matrix_at(self, step: int) -> np.ndarray:
        return self.matrices[step % self.period]

    def stacked(self) -> np.ndarray:
        """The array handed to the combine backend: ``(K, K)`` for a static
        schedule (so sparse/mesh backends stay eligible), ``(S, K, K)``
        otherwise."""
        return self.matrices[0] if self.static else self.matrices

    @functools.cached_property
    def _ir(self) -> ScheduleIR:
        return schedule_ir(self.matrices)

    def ir(self) -> ScheduleIR:
        """The sparse :class:`ScheduleIR` lowering of this schedule — what
        the ``sparse_dynamic``/``mesh_sparse_dynamic``/
        ``sparse_host_dynamic`` combine backends consume."""
        return self._ir

    @functools.cached_property
    def mean_matrix(self) -> np.ndarray:
        """E[A] over the period — its λ₂ is the *expected* per-step
        contraction a random schedule achieves (Boyd et al. 2006)."""
        return self.matrices.mean(axis=0)

    @property
    def mean_mixing_rate(self) -> float:
        return mixing_rate(self.mean_matrix)


def _static_schedule(topo: Topology, **kw) -> np.ndarray:
    return topo.matrix[None]


def _link_failure_schedule(topo: Topology, p: float = 0.2, period: int = 64,
                           seed: int = 0, **kw) -> np.ndarray:
    """Each edge drops i.i.d. with probability ``p`` at each step; the
    combination rule is re-applied to the surviving subgraph so every
    per-step matrix is doubly stochastic (a disconnected instant is fine —
    agreement only needs the *sequence* to mix)."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"link-failure probability must be in [0, 1], got {p}")
    rng = np.random.default_rng(seed)
    fn = _rule_fn(topo.rule)
    mats = []
    for _ in range(period):
        alive = [e for e in topo.edges if rng.random() >= p]
        mats.append(fn(topo.K, alive) if alive else np.eye(topo.K))
    return np.stack(mats)


def _gossip_schedule(topo: Topology, period: int = 64, seed: int = 0,
                     **kw) -> np.ndarray:
    """Randomized gossip: one uniformly-drawn edge per step does a
    half-half pairwise exchange; all other agents hold their state."""
    if not topo.edges:
        return np.eye(topo.K)[None]
    rng = np.random.default_rng(seed)
    mats = []
    for _ in range(period):
        l, k = topo.edges[rng.integers(len(topo.edges))]
        A = np.eye(topo.K)
        A[l, l] = A[k, k] = A[l, k] = A[k, l] = 0.5
        mats.append(A)
    return np.stack(mats)


def _round_robin_schedule(topo: Topology, **kw) -> np.ndarray:
    """Deterministic matchings via greedy edge coloring: each round's edges
    share no agent, so each round is a disjoint set of pairwise half-half
    exchanges; the full edge set is covered once per period."""
    if not topo.edges:
        return np.eye(topo.K)[None]
    rounds: list[list[tuple[int, int]]] = []
    busy: list[set[int]] = []
    for e in topo.edges:
        for r, members in enumerate(busy):
            if e[0] not in members and e[1] not in members:
                rounds[r].append(e)
                members.update(e)
                break
        else:
            rounds.append([e])
            busy.append(set(e))
    mats = []
    for matching in rounds:
        A = np.eye(topo.K)
        for l, k in matching:
            A[l, l] = A[k, k] = A[l, k] = A[k, l] = 0.5
        mats.append(A)
    return np.stack(mats)


SCHEDULES = {
    "static": _static_schedule,
    "link_failure": _link_failure_schedule,
    "gossip": _gossip_schedule,
    "round_robin": _round_robin_schedule,
}


def make_schedule(kind: str, topo: Topology, **kw) -> TopologySchedule:
    """Build a :class:`TopologySchedule` of the registered ``kind``.

    Keyword args are schedule-specific: ``p``/``period``/``seed`` for
    ``link_failure``, ``period``/``seed`` for ``gossip``; ``static`` and
    ``round_robin`` take none.
    """
    if kind not in SCHEDULES:
        raise ValueError(f"unknown topology schedule {kind!r}; "
                         f"available: {tuple(SCHEDULES)}")
    if topo.K == 1:
        return TopologySchedule(kind, topo, np.ones((1, 1, 1)))
    return TopologySchedule(kind, topo, SCHEDULES[kind](topo, **kw))
