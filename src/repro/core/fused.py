"""Fused combine-then-update outer step: the pytree driver.

One :func:`repro.kernels.dif_combine.fused_combine_update` launch per
parameter leaf replaces the trainer's unfused ``clip → opt.update →
strategy.apply/combine`` HLO chain — params, grads and moments are each
read once and written at most once per step (the traffic contract is
spelled in ``kernels/dif_combine/dif_combine.py``).  The only pre-kernel
work is the global-norm reduction (the clip scale must exist before the
first tile) and the tiny control scalars (step-selected schedule row,
CommSchedule gate, Adam bias corrections).

Leaves are flattened to (K, m) — a free reshape — and zero-padded to a
lane-aligned block multiple; the kernel keeps padded columns at zero, and
the pad is sliced off on the way out.  Packing the four buffer sets into
per-dtype (K, M) groups at every step would instead cost a full extra
read+write of everything (the concatenate materializes), defeating the
one-pass contract — which is why the driver launches per leaf; callers
holding pre-packed state use ``ops.fused_update_flat`` directly.

Qualification (:func:`fused_unsupported_reason`): the optimizer must carry
a :class:`repro.optim.FusedSpec` (custom ``Optimizer`` instances do not)
and the strategy must be one of atc / consensus / centralized / cta / none.
Mesh-sharded agent axes stay on the ppermute combine backends — the packed
layout is single-host (``launch/steps.py`` enforces this).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import global_norm_scale
from repro.optim.optimizers import AdamState, MomentumState, Optimizer

PyTree = Any

LANE = 128

# DiffusionStrategy -> kernel combine mode.  cta mixes *before* the
# gradient (the pre-combine runs through a combine backend); its post-step,
# like 'none', is the plain local update.  centralized is uniform-ATC.
_STRATEGY_MODES = {"atc": "atc", "consensus": "consensus",
                   "centralized": "atc", "cta": "local", "none": "local"}


def fused_unsupported_reason(opt: Optimizer, strategy: str) -> str | None:
    """Why (opt, strategy) cannot take the fused path — None when it can."""
    if opt.fused is None:
        return ("optimizer does not expose a FusedSpec (custom Optimizer "
                "instances must declare their per-leaf scalar math to run "
                "in-kernel); use sgd/momentum/adam/adamw or backend='dense'")
    if strategy not in _STRATEGY_MODES:
        return (f"diffusion strategy {strategy!r} has no fused composition; "
                f"supported: {tuple(_STRATEGY_MODES)}")
    return None


def _pad_geometry(m: int, block_m: int) -> tuple[int, int]:
    """(padded m, tile bm): small leaves round up to one lane-aligned tile,
    large leaves to the block multiple."""
    unit = LANE if m <= block_m else block_m
    m_pad = -(-m // unit) * unit
    return m_pad, min(m_pad, block_m)


def make_fused_outer(opt: Optimizer, strategy: str, comm, A,
                     *, grad_clip: float | None = None,
                     num_agents: int | None = None, block_m: int = 512,
                     interpret: bool | None = None):
    """Build ``outer(params, grads, opt_state, step) -> (params, opt_state)``
    — the fused replacement for the trainer's post-gradient block.

    ``comm``: a :class:`repro.core.update.CommSchedule`; ``A``: one (K, K)
    matrix or a stacked (S, K, K) schedule (ignored for local-mode
    strategies).  Raises ``ValueError`` when (opt, strategy) do not qualify
    (:func:`fused_unsupported_reason`).
    """
    from repro.kernels.dif_combine.dif_combine import fused_combine_update

    reason = fused_unsupported_reason(opt, strategy)
    if reason is not None:
        raise ValueError(f"fused outer update unavailable: {reason}")
    spec = opt.fused
    mode = _STRATEGY_MODES[strategy]

    An = np.asarray(A, np.float32) if A is not None else None
    if mode == "local":
        K = num_agents or (An.shape[-1] if An is not None else 1)
        table = np.eye(K, dtype=np.float32)[None]          # unread
    elif strategy == "centralized":
        K = num_agents or (An.shape[-1] if An is not None else None)
        if K is None:
            raise ValueError("fused centralized strategy needs num_agents "
                             "or a matrix to size the uniform table")
        table = np.full((1, K, K), 1.0 / K, np.float32)
    else:
        if An is None:
            raise ValueError(f"fused strategy {strategy!r} needs the "
                             f"combination matrix/schedule A")
        table = An[None] if An.ndim == 2 else An
        K = table.shape[-1]
    if num_agents is not None and K != num_agents:
        raise ValueError(
            f"combination table is over K={K} agents but the trainer runs "
            f"num_agents={num_agents}")
    S = table.shape[0]
    tab = jnp.asarray(table)

    kern = functools.partial(
        fused_combine_update, mode=mode, kind=spec.kind, lr=spec.lr,
        b1=spec.b1, b2=spec.b2, eps=spec.eps,
        weight_decay=spec.weight_decay, beta=spec.beta, block_m=block_m)

    def outer(params: PyTree, grads: PyTree, opt_state: PyTree, step):
        interp = (jax.default_backend() != "tpu" if interpret is None
                  else interpret)
        if grad_clip is not None:      # 0.0 is a valid (total) clip
            scale = jax.vmap(
                lambda g: global_norm_scale(g, grad_clip))(grads)
            scale = scale.reshape(K, 1).astype(jnp.float32)
        else:
            scale = jnp.ones((K, 1), jnp.float32)
        sel = jnp.mod(step, S).astype(jnp.int32).reshape(1, 1)
        gate = (comm.is_comm_step(step).astype(jnp.float32)
                if mode != "local" else jnp.zeros((), jnp.float32))
        if spec.kind == "adam":
            t = (opt_state.step + 1).astype(jnp.float32)
            bc1, bc2 = 1 - spec.b1 ** t, 1 - spec.b2 ** t
        else:
            bc1 = bc2 = jnp.ones((), jnp.float32)
        ctl = jnp.stack([gate, bc1, bc2]).reshape(1, 3).astype(jnp.float32)

        if spec.kind == "adam":
            mom_trees = (opt_state.mu, opt_state.nu)
        elif spec.kind == "momentum":
            mom_trees = (opt_state.velocity,)
        else:
            mom_trees = ()

        def leaf(p, g, *ms):
            shape = p.shape
            m = int(np.prod(shape[1:], dtype=np.int64)) if p.ndim > 1 else 1
            m_pad, bm = _pad_geometry(m, block_m)

            def prep(x):
                x = x.reshape(K, m)
                if m_pad != m:
                    x = jnp.pad(x, ((0, 0), (0, m_pad - m)))
                return x

            outs = kern(tab, sel, ctl, scale, prep(p), prep(g),
                        *(prep(x) for x in ms), block_m=bm,
                        interpret=interp)

            def post(x, like):
                if x is None:
                    return None
                if m_pad != m:
                    x = jax.lax.slice_in_dim(x, 0, m, axis=1)
                return x.reshape(like.shape)

            return (post(outs[0], p),) + tuple(
                post(o, ref) for o, ref in zip(outs[1:], ms))

        p_leaves, treedef = jax.tree.flatten(params)
        g_leaves = treedef.flatten_up_to(grads)
        mom_leaves = [treedef.flatten_up_to(t_) for t_ in mom_trees]
        results = [leaf(p, g, *ms)
                   for p, g, *ms in zip(p_leaves, g_leaves, *mom_leaves)]
        new_params = jax.tree.unflatten(treedef, [r[0] for r in results])
        if spec.kind == "adam":
            new_state = AdamState(
                opt_state.step + 1,
                jax.tree.unflatten(treedef, [r[1] for r in results]),
                jax.tree.unflatten(treedef, [r[2] for r in results]))
        elif spec.kind == "momentum":
            new_state = MomentumState(
                jax.tree.unflatten(treedef, [r[1] for r in results]))
        else:
            new_state = opt_state
        return new_params, new_state

    return outer
