"""Dif-MAML core: decentralized meta-learning over a graph of agents.

The paper's contribution (Algorithm 1) lives here:
  - topology.py      combination matrices A (Assumption 6) + mixing rate lambda_2
  - maml.py          inner adaptation and the stochastic meta-gradient (eq. 4)
  - diffusion.py     Adapt-then-Combine over the agent axis (eq. 6a/6b)
  - meta_trainer.py  the full decentralized trainer + baselines
"""
from repro.core.meta_trainer import MetaConfig, TrainState, init_state, make_meta_step, make_eval_fn
from repro.core import topology, maml, diffusion

__all__ = ["MetaConfig", "TrainState", "init_state", "make_meta_step",
           "make_eval_fn", "topology", "maml", "diffusion"]
