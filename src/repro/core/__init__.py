"""Dif-MAML core: decentralized meta-learning over a graph of agents.

The paper's contribution (Algorithm 1) lives here:
  - topology.py      combination matrices A (Assumption 6), mixing rate
                     lambda_2, and per-step TopologySchedules (static,
                     link-failure, gossip, round-robin)
  - maml.py          inner adaptation and the stochastic meta-gradient (eq. 4)
  - diffusion.py     combine backends over the agent axis (eq. 6b)
  - update.py        DiffusionStrategy (atc/cta/consensus/none/centralized),
                     InnerAlgo registry, CommSchedule
  - meta_trainer.py  the InnerAlgo x DiffusionStrategy x CommSchedule
                     assembly + nested TopologyConfig/UpdateConfig
"""
from repro.core.meta_trainer import (MetaConfig, TopologyConfig, UpdateConfig,
                                     TrainState, init_state, make_meta_step,
                                     make_eval_fn)
from repro.core import topology, maml, diffusion, update

__all__ = ["MetaConfig", "TopologyConfig", "UpdateConfig", "TrainState",
           "init_state", "make_meta_step", "make_eval_fn",
           "topology", "maml", "diffusion", "update"]
