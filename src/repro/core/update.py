"""First-class decentralized outer-update composition.

The paper's central experimental axis is the *outer* update structure: who
mixes with whom (:mod:`repro.core.topology`), **how** the mix composes with
the local gradient step (this module's :class:`DiffusionStrategy`), and
**when** communication happens (:class:`CommSchedule`).  The trainer
(:func:`repro.core.meta_trainer.make_meta_step`) is a thin assembly of

    InnerAlgo × DiffusionStrategy × CommSchedule

with each factor an independently pluggable registry entry.

DiffusionStrategy registry
==========================

A strategy composes the per-agent optimizer update ``u_k`` (already produced
by InnerAlgo + outer optimizer) with the combine step.  ``apply`` is a pure
``(params, updates, combine_fn, step) -> params`` function; ``combine_fn``
is a :data:`repro.core.diffusion.CombineFn` (``combine(phi, step)``), and
``step`` threads the traced counter so stacked topology schedules stay
jit-compatible.

``atc``          Adapt-then-Combine (paper Algorithm 1, eq. 6a/6b):
                 ``w' = A (w + u)``.  The paper's headline strategy — the
                 combine sees the freshest local information.
``cta``          Combine-then-Adapt (Sayed 2014 diffusion variant): the
                 iterate is mixed **before** the meta-gradient is taken, so
                 the inner adaptation, meta-gradient, and optimizer update
                 are all evaluated at the mixed point ``ψ = A w``:
                 ``w' = ψ + u(ψ)``.  Declared via ``pre_combine=True`` —
                 the trainer mixes ahead of the gradient computation and
                 ``apply`` is the plain local update.
``consensus``    consensus / DGD composition: mix the previous iterates,
                 apply the update evaluated at the **own** previous iterate
                 — ``w' = A w + u(w)`` (this is exactly
                 :func:`repro.core.diffusion.cta_step`, revived from dead
                 code).
``none``         non-cooperative baseline: ``w' = w + u`` (A = I).
``centralized``  every agent receives the centroid of the adapted iterates
                 (A = (1/K)·11ᵀ), the paper's centralized reference;
                 ignores the topology entirely.

InnerAlgo registry
==================

Names the inner meta-gradient algorithm.  The math lives unchanged in
:mod:`repro.core.maml`; the registry only validates the name and carries
the mode string the trainer passes through (``maml`` exact second-order,
``fomaml`` first-order, ``reptile`` update-direction, ``maml_naive``
cross-validation form).

CommSchedule
============

When to communicate: ``every=n`` runs the combine only on steps where
``step ≡ n−1 (mod n)`` (the legacy ``combine_every`` semantics).  The
trainer folds the decision into ``lax.cond`` so skipped steps execute *no*
combine matmul or collective — unlike the old ``jnp.where`` path, which
paid the full communication cost every step and discarded the result.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core import diffusion

PyTree = Any

__all__ = [
    "DiffusionStrategy",
    "register_strategy",
    "update_strategies",
    "get_strategy",
    "InnerAlgo",
    "inner_algos",
    "get_inner_algo",
    "CommSchedule",
    "local_update",
]


# ---------------------------------------------------------------------------
# DiffusionStrategy registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DiffusionStrategy:
    """One registered outer-update composition.

    ``apply(params, updates, combine_fn, step) -> params`` is the pure
    composition; ``params`` arrive already mixed when ``pre_combine`` is
    set (the trainer runs ``combine_fn`` *before* the meta-gradient).

    ``communicates``    whether the strategy moves bytes between agents at
                        all (gates the :class:`CommSchedule`); ``none`` and
                        the K=1 degenerate case don't.
    ``needs_combine_fn`` whether ``apply`` consumes the topology's combine
                        (``centralized`` averages regardless of the graph).
    ``pre_combine``     mix the iterate before the gradient step (``cta``).
    """

    name: str
    apply: Callable[[PyTree, PyTree, diffusion.CombineFn | None, Any], PyTree]
    communicates: bool = True
    needs_combine_fn: bool = True
    pre_combine: bool = False


_STRATEGIES: dict[str, DiffusionStrategy] = {}


def register_strategy(name: str, **flags: bool):
    """Decorator: register an ``apply`` composition under ``name``."""

    def deco(apply):
        _STRATEGIES[name] = DiffusionStrategy(name, apply, **flags)
        return apply

    return deco


def update_strategies() -> tuple[str, ...]:
    return tuple(_STRATEGIES)


def get_strategy(name: str) -> DiffusionStrategy:
    s = _STRATEGIES.get(name)
    if s is None:
        raise ValueError(f"unknown diffusion strategy {name!r}; "
                         f"registered: {update_strategies()}")
    return s


def local_update(params: PyTree, updates: PyTree) -> PyTree:
    """The communication-free outer update w' = w + u — the 'none' strategy
    and the skip branch of the CommSchedule gate, by construction the same
    function."""
    return jax.tree.map(lambda p, u: p + u, params, updates)


@register_strategy("atc")
def _atc(params, updates, combine_fn, step):
    """w' = A (w + u): paper Algorithm 1 (eq. 6a adapt, 6b combine)."""
    return diffusion.atc_step(params, updates, lambda p: combine_fn(p, step))


@register_strategy("cta", pre_combine=True)
def _cta(params, updates, combine_fn, step):
    """w' = ψ + u(ψ) with ψ = A w: the mix happened before the gradient
    (``pre_combine``), so the remaining composition is the local update."""
    return local_update(params, updates)


@register_strategy("consensus")
def _consensus(params, updates, combine_fn, step):
    """w' = A w + u(w): consensus/DGD — gradient at the own previous
    iterate, mix of the previous iterates (diffusion.cta_step revived)."""
    return diffusion.cta_step(params, updates, lambda p: combine_fn(p, step))


@register_strategy("none", communicates=False, needs_combine_fn=False)
def _none(params, updates, combine_fn, step):
    """w' = w + u: non-cooperative baseline (A = I)."""
    return local_update(params, updates)


@register_strategy("centralized", needs_combine_fn=False)
def _centralized(params, updates, combine_fn, step):
    """Every agent receives the centroid of the adapted iterates — the
    paper's centralized reference (A = (1/K)·11ᵀ, graph-independent)."""
    return diffusion.centralized_combine(local_update(params, updates))


# ---------------------------------------------------------------------------
# InnerAlgo registry (names only — math unchanged in core/maml.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InnerAlgo:
    """A named inner meta-gradient algorithm; ``mode`` is the string
    :func:`repro.core.maml.multi_task_meta_grad` dispatches on."""

    name: str
    mode: str
    order: int                 # derivative order of the meta-gradient
    doc: str = ""


_INNER: dict[str, InnerAlgo] = {
    "maml": InnerAlgo("maml", "maml", 2,
                      "exact second-order meta-gradient (paper eq. 4)"),
    "fomaml": InnerAlgo("fomaml", "fomaml", 1,
                        "first-order: curvature term dropped"),
    "reptile": InnerAlgo("reptile", "reptile", 1,
                         "update direction = (w_adapted - w)"),
    "maml_naive": InnerAlgo("maml_naive", "maml_naive", 2,
                            "differentiate-through-the-update "
                            "cross-validation form"),
}


def inner_algos() -> tuple[str, ...]:
    return tuple(_INNER)


def get_inner_algo(name: str) -> InnerAlgo:
    a = _INNER.get(name)
    if a is None:
        raise ValueError(f"unknown inner algorithm {name!r}; "
                         f"registered: {inner_algos()}")
    return a


# ---------------------------------------------------------------------------
# CommSchedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CommSchedule:
    """Communicate every ``every``-th step (legacy ``combine_every``
    phase: the combine runs when ``step % every == every - 1``, so a fresh
    run's first communication lands on step ``every - 1``)."""

    every: int = 1

    def __post_init__(self):
        if self.every < 1:
            raise ValueError(f"CommSchedule.every must be >= 1, "
                             f"got {self.every}")

    @property
    def always(self) -> bool:
        return self.every == 1

    def is_comm_step(self, step) -> Any:
        """Predicate usable on a traced step index."""
        return (step % self.every) == self.every - 1
