"""Pallas TPU flash attention backward (FlashAttention-2 style).

Two kernels, both recomputing the logit tile from (q, k) + the forward's
per-row logsumexp — no O(S²) residuals:

  dkv kernel  grid (B, H, S_k/bk, S_q/bq):  per KV block, accumulate
              dK = Σᵢ dSᵀ Qᵢ and dV = Σᵢ Pᵀ dOᵢ in VMEM scratch over the
              (minor-most) query-block loop
  dq kernel   grid (B, H, S_q/bq, S_k/bk):  per Q block, accumulate
              dQ = Σⱼ dS Kⱼ over the KV-block loop

with  P = exp(S − lse),  dS = P ⊙ (dP − D) · scale,  dP = dO Vᵀ,
      D = rowsum(dO ⊙ O)  (precomputed in jnp — O(S·d)).

Together with the forward in flash_attention.py this completes the fused
attention path: forward + backward never round-trip an (S, S) tensor
through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(qpos, kpos, causal, window):
    m = jnp.ones(qpos.shape, jnp.bool_)
    if causal:
        m = m & (kpos <= qpos)
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale, block_q, block_k, causal, window):
    ji = pl.program_id(2)          # kv block
    ii = pl.program_id(3)          # q block (minor: sequential)
    nq = pl.num_programs(3)

    @pl.when(ii == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)          # (bq, d)
    lse = lse_ref[0, 0].astype(jnp.float32)        # (bq, 1)
    dsum = dsum_ref[0, 0].astype(jnp.float32)      # (bq, 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)
    qpos = ii * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ji * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
    p = jnp.exp(s - lse)                                             # (bq,bk)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))        # (bq,bk)
    ds = p * (dp - dsum) * scale
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(ii == nq - 1)
    def _emit():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
               dq_ref, dq_scr, *, scale, block_q, block_k, causal, window):
    ii = pl.program_id(2)          # q block
    ji = pl.program_id(3)          # kv block (minor)
    nk = pl.num_programs(3)

    @pl.when(ji == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    dsum = dsum_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    qpos = ii * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ji * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(_mask(qpos, kpos, causal, window), s, NEG_INF)
    p = jnp.exp(s - lse)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - dsum) * scale
    dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ji == nk - 1)
    def _emit():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal=True, window=None,
                        block_q=128, block_k=128, interpret=False):
    """q/k/v/out/do: (B, H, S, d); lse: (B, H, S).  Returns (dq, dk, dv)."""
    B, H, S, d = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    if S % block_q or Sk % block_k:
        raise ValueError(
            f"flash_attention_bwd needs block-aligned sequence lengths: "
            f"seq_q={S} % block_q={block_q} = {S % block_q}, "
            f"seq_k={Sk} % block_k={block_k} = {Sk % block_k} — pad "
            f"the sequence or pick blocks dividing it")
    scale = 1.0 / np.sqrt(d)
    dsum = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                   axis=-1)                                       # (B,H,S)
    lse4 = lse[..., None]
    dsum4 = dsum[..., None]

    common = dict(scale=scale, block_q=block_q, block_k=block_k,
                  causal=causal, window=window)
    dkv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(B, H, Sk // block_k, S // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, j, i: (b, h, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse4, dsum4)
    dk, dv = dkv

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(B, H, S // block_q, Sk // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse4, dsum4)
    return dq, dk, dv
