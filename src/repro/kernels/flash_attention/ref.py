"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None) -> jax.Array:
    """q/k/v: (B, H, S, d).  Full-materialization masked softmax."""
    B, H, S, d = q.shape
    Sk = k.shape[2]
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
