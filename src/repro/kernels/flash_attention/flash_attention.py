"""Pallas TPU flash attention (forward), causal + GQA + sliding window.

Online-softmax blocked attention: grid (B, H, S_q/bq, S_k/bk); the KV block
index is minor-most, so TPU iterates it sequentially per query block and the
(m, l, acc) running statistics live in VMEM scratch across that loop.

Blocks are MXU-aligned: bq × d and bk × d tiles feed the systolic array
directly; masking (causal / sliding-window) is applied on the bq × bk logit
tile with position iotas — no (S, S) mask is ever materialized in HBM.
This replaces the O(S²) logits round-trip of the jnp reference with an
O(S·d) working set: the kernel is the standard remedy once the memory
roofline term is dominated by attention intermediates (prefill_32k).

Backward passes: ``ops.flash_attention`` recomputes with the jnp reference
(exact gradients, kernel-grade forward); ``ops.flash_attention_fused`` pairs
this forward (which also emits the per-row logsumexp) with the fully-fused
Pallas backward in flash_bwd.py — neither direction round-trips an (S, S)
tensor through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
                  acc_scr, *, scale: float, block_q: int, block_k: int,
                  seq_k: int, causal: bool, window: int | None):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)                     # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                                     # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                  # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                         # (bq, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))                     # (bq, d)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)
        # per-row logsumexp — the only residual the fused backward needs
        lse_ref[0, 0] = (m_scr[...]
                         + jnp.log(jnp.maximum(l_scr[...], 1e-30))
                         ).astype(lse_ref.dtype)


def flash_attention_fwd_lse(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, window: int | None = None,
                            block_q: int = 128, block_k: int = 128,
                            interpret: bool = False):
    """As flash_attention_fwd but also returns the per-row logsumexp
    (B, H, S) consumed by the fused Pallas backward (flash_bwd.py)."""
    B, H, S, d = q.shape
    Sk = k.shape[2]
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    if S % block_q or Sk % block_k:
        raise ValueError(
            f"flash_attention_fwd_lse needs block-aligned sequence "
            f"lengths: seq_q={S} % block_q={block_q} = {S % block_q}, "
            f"seq_k={Sk} % block_k={block_k} = {Sk % block_k} — pad "
            f"the sequence or pick blocks dividing it")
    scale = 1.0 / np.sqrt(d)
    grid = (B, H, S // block_q, Sk // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_k=Sk, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, d), q.dtype),
            jax.ShapeDtypeStruct((B, H, S, 1), jnp.float32),
        ],
        scratch_shapes=[
            # running max / denom / accumulator — f32 VMEM, persistent
            # across the (minor-most) KV grid dimension
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention_fwd(q, k, v, *, causal=True, window=None, block_q=128,
                        block_k=128, interpret=False):
    """q/k/v: (B, H, S, d) (GQA pre-expanded or H==KV) → (B, H, S, d)."""
    out, _ = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    return out
