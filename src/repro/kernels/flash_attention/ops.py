"""Public flash-attention op: Pallas forward + exact recompute backward.

``jax.custom_vjp``: the forward runs the Pallas kernel; the backward
recomputes attention with the jnp reference and differentiates it — exact
gradients with kernel-grade forward memory behavior (the standard
recompute-in-backward pattern; a fused Pallas backward is a further
optimization, not a correctness requirement).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, window=None, block_q=128,
                    block_k=128, interpret=False):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def _fwd(q, k, v, causal, window, block_q, block_k, interpret):
    out = flash_attention_fwd(q, k, v, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out, (q, k, v)


def _bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


def gqa_flash_attention(q, k, v, **kw):
    """q: (B, S, H, d); k/v: (B, S, KV, d) — model-layout convenience
    wrapper (transposes + GQA expansion)."""
    H, KV = q.shape[2], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    out = flash_attention(q.swapaxes(1, 2), k.swapaxes(1, 2),
                          v.swapaxes(1, 2), **kw)
    return out.swapaxes(1, 2)


# ---------------------------------------------------------------------------
# Fully-fused variant: Pallas forward AND Pallas backward (flash_bwd.py).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_fused(q, k, v, causal=True, window=None, block_q=128,
                          block_k=128, interpret=False):
    from repro.kernels.flash_attention.flash_attention import flash_attention_fwd_lse
    out, _ = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                     block_q=block_q, block_k=block_k,
                                     interpret=interpret)
    return out


def _fused_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    from repro.kernels.flash_attention.flash_attention import flash_attention_fwd_lse
    out, lse = flash_attention_fwd_lse(q, k, v, causal=causal, window=window,
                                       block_q=block_q, block_k=block_k,
                                       interpret=interpret)
    return out, (q, k, v, out, lse[..., 0])


def _fused_bwd(causal, window, block_q, block_k, interpret, res, g):
    from repro.kernels.flash_attention.flash_bwd import flash_attention_bwd
    q, k, v, out, lse = res
    return flash_attention_bwd(q, k, v, out, lse, g, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret)


flash_attention_fused.defvjp(_fused_fwd, _fused_bwd)
