"""Pallas TPU kernel for the Dif-MAML combine step (paper eq. 6b).

    out[k, m] = Σ_l A[l, k] · φ[l, m]

φ is the stack of intermediate states (K agents × flattened parameter
chunk).  After the neighbor exchange lands the K rows in HBM, this kernel
fuses the weighted reduction over agents with the write of the new launch
model — one pass over the parameter bytes instead of K-1 separate
axpy passes (the combine is HBM-bandwidth-bound: K·|w| reads, |w| writes).

Tiling: grid over (K, M/bm).  Each program reads a (K, bm) tile of φ plus
the K×K combination matrix (tiny, VMEM-resident) and writes a (1, bm) tile.
bm is lane-aligned (multiple of 128) so the reduction runs on the VPU at
full width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(a_ref, phi_ref, out_ref):
    k = pl.program_id(0)
    w = jax.lax.dynamic_slice_in_dim(a_ref[...], k, 1, axis=1)   # (K, 1)
    phi = phi_ref[...]                                           # (K, bm)
    acc = jnp.sum(phi.astype(jnp.float32) * w.astype(jnp.float32), axis=0,
                  keepdims=True)                                 # (1, bm)
    out_ref[...] = acc.astype(out_ref.dtype)


def dif_combine(A: jax.Array, phi: jax.Array, *, block_m: int = 512,
                interpret: bool = False) -> jax.Array:
    """A: (K, K) doubly-stochastic; phi: (K, M).  Returns (K, M)."""
    K, M = phi.shape
    assert A.shape == (K, K)
    assert M % block_m == 0, (M, block_m)
    grid = (K, M // block_m)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, K), lambda k, m: (0, 0)),
            pl.BlockSpec((K, block_m), lambda k, m: (0, m)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda k, m: (k, m)),
        out_shape=jax.ShapeDtypeStruct((K, M), phi.dtype),
        interpret=interpret,
    )(A, phi)
