"""Pallas TPU kernels for the Dif-MAML outer update: one pass over the
parameter bytes.

Memory-traffic contract (per step, per (K, M) dtype group; P = K·M·itemsize
parameter-set bytes, F = K·M·4 fp32-moment bytes)
================================================================

Unfused (clip → Adam moments → apply → combine as separate HLO), counting
each buffer's HBM round-trips:

  =================  =============================================  =======
  stage              traffic                                        bytes
  =================  =============================================  =======
  global-norm pass   read g                                         1P
  clip scale         read g, write g_c                              2P
  Adam moments       read g_c (×2), mu, nu; write mu, nu            2P + 4F
  update direction   read mu, nu; write u                           1P + 2F
  apply φ = w + u    read w, u; write φ                             3P
  combine A·φ        read φ, write w'                               2P
  =================  =============================================  =======

  total ≈ 11P + 6F  — measured 15.1P on compiled XLA:CPU HLO at f32
  (XLA fuses some of the above; the combine einsum and the moment updates
  stay separate because each has a different output set).

Fused (``fused_combine_update``): everything between the norm pass and the
new launch model is **one kernel** —

  =================  =============================================  =======
  global-norm pass   read g (the clip scale must precede tile 0)    1P
  fused kernel       read w, g, mu, nu; write w', mu, nu            3P + 4F
  =================  =============================================  =======

  total = 4P + 4F: each buffer is read once and written at most once.
  At f32 (F = P) that is 8P vs ~15P measured unfused (0.53×); at bf16
  params/grads with fp32 moments (F = 2P) it is 12 bf16-units vs ~27
  measured (0.44×) — the `outer_update` benchmark row pins both.

Per (K, bm) tile the fused kernel (a) gathers the traced step's combination
matrix from the stacked ``(S, K, K)`` schedule table by one-hot reduction
(no scalar prefetch — runs on both supported JAX lines), (b) applies the
pre-computed per-agent global-norm clip scale, (c) advances the optimizer
moments in fp32 (``repro.optim.optimizers`` scalar math — the same
expressions the HLO path evaluates), and (d) emits the new launch model for
the ATC (``w' = A·(w + u)``), consensus (``w' = A·w + u``) or local
(``w' = w + u``) composition.  ``combine_every`` gating is branch-free:
``A_eff = gate·A_s + (1 − gate)·I``, so skipped steps still advance the
moments while the mix degenerates to the identity.

``dif_combine`` is the original combine-only kernel (paper eq. 6b,
``out[k, m] = Σ_l A[l, k]·φ[l, m]``): grid over (K, M/bm), one (K, bm)
φ-tile read per output row — one pass over the parameter bytes instead of
K−1 separate axpy passes, still used by the ``pallas`` combine backend and
the ``cta`` pre-mix.

Tiling: bm must be lane-aligned (multiple of 128) so reductions run on the
VPU at full width; K rides the sublane dim (K ≥ 8 tiles exactly at f32).
``interpret=True`` runs the same kernels on CPU for CI parity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_KINDS = ("sgd", "momentum", "adam")
_MODES = ("atc", "consensus", "local")


def _combine_kernel(a_ref, phi_ref, out_ref):
    k = pl.program_id(0)
    w = jax.lax.dynamic_slice_in_dim(a_ref[...], k, 1, axis=1)   # (K, 1)
    phi = phi_ref[...]                                           # (K, bm)
    acc = jnp.sum(phi.astype(jnp.float32) * w.astype(jnp.float32), axis=0,
                  keepdims=True)                                 # (1, bm)
    out_ref[...] = acc.astype(out_ref.dtype)


def _check_block(M: int, block_m: int) -> None:
    if block_m < 1 or block_m % 128:
        raise ValueError(
            f"block_m={block_m} must be a positive multiple of the 128-lane "
            f"width (full-width VPU tiles)")
    if M % block_m:
        raise ValueError(
            f"packed feature dim M={M} is not a multiple of "
            f"block_m={block_m}; zero-pad the buffer to the block multiple "
            f"(pack_pytree / the fused tree driver do this) or pick a "
            f"block_m dividing M")


def dif_combine(A: jax.Array, phi: jax.Array, *, block_m: int = 512,
                interpret: bool = False) -> jax.Array:
    """A: (K, K) doubly-stochastic; phi: (K, M).  Returns (K, M)."""
    K, M = phi.shape
    if A.shape != (K, K):
        raise ValueError(
            f"combination matrix shape {A.shape} does not match the "
            f"K={K} stacked agents of phi {phi.shape}; need A of "
            f"shape ({K}, {K})")
    _check_block(M, block_m)
    grid = (K, M // block_m)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, K), lambda k, m: (0, 0)),
            pl.BlockSpec((K, block_m), lambda k, m: (0, m)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda k, m: (k, m)),
        out_shape=jax.ShapeDtypeStruct((K, M), phi.dtype),
        interpret=interpret,
    )(A, phi)


# ---------------------------------------------------------------------------
# Fused combine-then-update kernel
# ---------------------------------------------------------------------------

def _fused_kernel(tab_ref, sel_ref, ctl_ref, scale_ref, w_ref, g_ref, *rest,
                  mode: str, kind: str, lr: float, b1: float, b2: float,
                  eps: float, weight_decay: float, beta: float):
    from repro.optim import optimizers as om

    w32 = w_ref[...].astype(jnp.float32)                        # (K, bm)
    g32 = (g_ref[...].astype(jnp.float32)
           * scale_ref[...].astype(jnp.float32))                # clip, (K,1)·

    if kind == "adam":
        mu_ref, nu_ref, w_out, mu_out, nu_out = rest
        bc1, bc2 = ctl_ref[0, 1], ctl_ref[0, 2]
        mu = om.adam_mu(mu_ref[...], g32, b1)
        nu = om.adam_nu(nu_ref[...], g32, b2)
        u = om.adam_direction(mu, nu, bc1, bc2, lr=lr, eps=eps,
                              weight_decay=weight_decay, p32=w32)
        mu_out[...] = mu
        nu_out[...] = nu
    elif kind == "momentum":
        vel_ref, w_out, vel_out = rest
        v = om.momentum_velocity(vel_ref[...].astype(jnp.float32), g32, beta)
        u = om.momentum_direction(v, lr=lr)
        vel_out[...] = v.astype(vel_out.dtype)
    else:                                                       # sgd
        (w_out,) = rest
        u = om.sgd_direction(g32, lr=lr)

    if mode == "local":
        new = w32 + u
    else:
        K = w32.shape[0]
        S = tab_ref.shape[0]
        # one-hot gather of the traced step's matrix from the (S, K, K)
        # schedule table: a VPU reduction, no scalar-prefetch grid needed
        sel = sel_ref[0, 0]
        hot = (jax.lax.broadcasted_iota(jnp.int32, (S, 1, 1), 0)
               == sel).astype(jnp.float32)
        A = jnp.sum(tab_ref[...].astype(jnp.float32) * hot, axis=0)  # (K, K)
        # branch-free CommSchedule gating: skipped steps mix with I
        gate = ctl_ref[0, 0]
        eye = (jax.lax.broadcasted_iota(jnp.int32, (K, K), 0)
               == jax.lax.broadcasted_iota(jnp.int32, (K, K), 1)
               ).astype(jnp.float32)
        A_eff = gate * A + (1.0 - gate) * eye
        phi = w32 + u if mode == "atc" else w32
        # out[k] = Σ_l A_eff[l, k] · phi[l]
        mixed = jax.lax.dot_general(A_eff, phi, (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        new = mixed if mode == "atc" else mixed + u
    w_out[...] = new.astype(w_out.dtype)


def fused_combine_update(table: jax.Array, sel: jax.Array, ctl: jax.Array,
                         scale: jax.Array, params: jax.Array,
                         grads: jax.Array, mu: jax.Array | None = None,
                         nu: jax.Array | None = None, *, mode: str = "atc",
                         kind: str = "adam", lr: float, b1: float = 0.9,
                         b2: float = 0.999, eps: float = 1e-8,
                         weight_decay: float = 0.0, beta: float = 0.9,
                         block_m: int = 512, interpret: bool = False):
    """One-pass combine-then-update over a packed (K, M) dtype group.

    Arguments (see module docstring for the traffic contract):

    ``table``  (S, K, K) stacked schedule (S=1 for a static graph); for
               ``mode='local'`` it is unread but must still be (S, K, K).
    ``sel``    (1, 1) int32 — the traced ``step % S`` row index.
    ``ctl``    (1, 3) float32 — ``[gate, bc1, bc2]``: the CommSchedule
               gate (1.0 = mix this step) and the Adam bias corrections
               (ignored for sgd/momentum).
    ``scale``  (K, 1) float32 per-agent global-norm clip scale (ones when
               unclipped).
    ``params``/``grads``  (K, M), any float dtype (one dtype group).
    ``mu``/``nu``  fp32 moment buffers: both for ``kind='adam'``; ``mu`` =
               velocity (param dtype) for ``'momentum'``; neither for
               ``'sgd'``.

    Returns ``(new_params, new_mu, new_nu)`` with ``None`` for absent
    moment buffers.  Zero-padded columns stay zero through the kernel
    (eps > 0 keeps the Adam direction finite at 0/0), so callers may pad
    ragged leaves to the block multiple and slice the pad off.
    """
    if kind not in _KINDS:
        raise ValueError(f"unknown optimizer kind {kind!r}; one of {_KINDS}")
    if mode not in _MODES:
        raise ValueError(f"unknown combine mode {mode!r}; one of {_MODES}")
    K, M = params.shape
    if grads.shape != (K, M):
        raise ValueError(
            f"grads shape {grads.shape} does not match params {params.shape}")
    if table.ndim != 3 or table.shape[1:] != (K, K):
        raise ValueError(
            f"schedule table shape {table.shape} does not match the K={K} "
            f"stacked agents of params {params.shape}; need (S, {K}, {K})")
    _check_block(M, block_m)
    n_mom = {"sgd": 0, "momentum": 1, "adam": 2}[kind]
    moments = [m for m in (mu, nu)[:n_mom]]
    if len([m for m in (mu, nu) if m is not None]) != n_mom:
        raise ValueError(
            f"optimizer kind {kind!r} takes exactly {n_mom} moment "
            f"buffer(s); got mu={'set' if mu is not None else None}, "
            f"nu={'set' if nu is not None else None}")
    for name, m in zip(("mu", "nu"), moments):
        if m.shape != (K, M):
            raise ValueError(
                f"{name} shape {m.shape} does not match params "
                f"{params.shape}")
    if kind == "adam":
        for name, m in zip(("mu", "nu"), moments):
            if m.dtype != jnp.float32:
                raise ValueError(
                    f"adam moment {name} must be float32 (fp32 moments are "
                    f"the fused contract), got {m.dtype}")

    S = table.shape[0]
    grid = (M // block_m,)
    row = lambda m: (0, m)
    fixed = lambda *_: (0,) * 3
    in_specs = [
        pl.BlockSpec((S, K, K), fixed),
        pl.BlockSpec((1, 1), lambda m: (0, 0)),
        pl.BlockSpec((1, 3), lambda m: (0, 0)),
        pl.BlockSpec((K, 1), lambda m: (0, 0)),
        pl.BlockSpec((K, block_m), row),
        pl.BlockSpec((K, block_m), row),
    ] + [pl.BlockSpec((K, block_m), row) for _ in moments]
    out_shape = [jax.ShapeDtypeStruct((K, M), params.dtype)] + [
        jax.ShapeDtypeStruct((K, M), m.dtype) for m in moments]
    out_specs = [pl.BlockSpec((K, block_m), row) for _ in out_shape]

    kernel = functools.partial(_fused_kernel, mode=mode, kind=kind, lr=lr,
                               b1=b1, b2=b2, eps=eps,
                               weight_decay=weight_decay, beta=beta)
    outs = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(table, sel, ctl, scale, params, grads, *moments)
    outs = list(outs) + [None, None]
    return outs[0], outs[1] if n_mom >= 1 else None, \
        outs[2] if n_mom >= 2 else None
