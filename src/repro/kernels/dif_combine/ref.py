"""Pure-jnp oracles for the combine and combine-then-update kernels."""
import jax
import jax.numpy as jnp

from repro.optim import optimizers as om


def dif_combine_ref(A: jax.Array, phi: jax.Array) -> jax.Array:
    """out[k] = Σ_l A[l, k] φ[l]  (float32 accumulation)."""
    out = jnp.einsum("lk,lm->km", A.astype(jnp.float32),
                     phi.astype(jnp.float32))
    return out.astype(phi.dtype)


def fused_update_ref(table, sel, ctl, scale, params, grads, mu=None, nu=None,
                     *, mode: str = "atc", kind: str = "adam", lr: float,
                     b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                     weight_decay: float = 0.0, beta: float = 0.9):
    """Same math as :func:`..dif_combine.fused_combine_update` in plain jnp
    (fp32 throughout, identity-blend gating) — the kernel parity oracle.
    Takes/returns the same (K, M) buffers and ``(w', mu', nu')`` tuple."""
    w32 = params.astype(jnp.float32)
    g32 = grads.astype(jnp.float32) * scale.astype(jnp.float32)
    new_mu = new_nu = None
    if kind == "adam":
        bc1, bc2 = ctl[0, 1], ctl[0, 2]
        new_mu = om.adam_mu(mu, g32, b1)
        new_nu = om.adam_nu(nu, g32, b2)
        u = om.adam_direction(new_mu, new_nu, bc1, bc2, lr=lr, eps=eps,
                              weight_decay=weight_decay, p32=w32)
    elif kind == "momentum":
        v = om.momentum_velocity(mu.astype(jnp.float32), g32, beta)
        u = om.momentum_direction(v, lr=lr)
        new_mu = v.astype(mu.dtype)
    else:
        u = om.sgd_direction(g32, lr=lr)
    if mode == "local":
        new = w32 + u
    else:
        K = params.shape[0]
        A = table.astype(jnp.float32)[sel[0, 0]]
        gate = ctl[0, 0]
        A_eff = gate * A + (1.0 - gate) * jnp.eye(K, dtype=jnp.float32)
        phi = w32 + u if mode == "atc" else w32
        mixed = jnp.einsum("lk,lm->km", A_eff, phi)
        new = mixed if mode == "atc" else mixed + u
    return new.astype(params.dtype), new_mu, new_nu
