"""Pure-jnp oracle for the combine kernel."""
import jax
import jax.numpy as jnp


def dif_combine_ref(A: jax.Array, phi: jax.Array) -> jax.Array:
    """out[k] = Σ_l A[l, k] φ[l]  (float32 accumulation)."""
    out = jnp.einsum("lk,lm->km", A.astype(jnp.float32),
                     phi.astype(jnp.float32))
    return out.astype(phi.dtype)
