"""Jit'd public wrapper: combine a whole parameter pytree with one fused
kernel launch per leaf (leaves flattened/padded to lane multiples)."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dif_combine.dif_combine import dif_combine
from repro.kernels.dif_combine.ref import dif_combine_ref

PyTree = Any


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def combine_flat(A: jax.Array, phi: jax.Array, block_m: int = 512,
                 interpret: bool = False) -> jax.Array:
    return dif_combine(A, phi, block_m=block_m, interpret=interpret)


def combine_tree(A: jax.Array, phi: PyTree, *, block_m: int = 512,
                 interpret: bool = False) -> PyTree:
    """Combine every leaf (leading axis = agents).  Leaves are flattened and
    zero-padded up to a block multiple, combined, and reshaped back."""
    K = A.shape[0]

    def leaf(x):
        shape = x.shape
        flat = x.reshape(K, -1)
        M = flat.shape[1]
        pad = (-M) % block_m
        if pad:
            flat = jnp.pad(flat, ((0, 0), (0, pad)))
        out = combine_flat(A, flat, block_m=block_m, interpret=interpret)
        return out[:, :M].reshape(shape)

    return jax.tree.map(leaf, phi)
