"""Jit'd public wrappers over the fused dif_combine kernel.

``combine_tree`` delegates to the registry's packed flatten-to-(K, M) path
(``repro.core.diffusion.make_pallas_combine``) so there is exactly one
tree-level pallas combine implementation in the codebase: leaves are
flattened, grouped by dtype, zero-padded to a lane-aligned block multiple,
combined in one kernel launch per group, and sliced back.
"""
from __future__ import annotations

import functools
from typing import Any

import jax

from repro.kernels.dif_combine.dif_combine import (dif_combine,
                                                   fused_combine_update)

PyTree = Any


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def combine_flat(A: jax.Array, phi: jax.Array, block_m: int = 512,
                 interpret: bool = False) -> jax.Array:
    """Combine one pre-packed (K, M) buffer; M must divide by block_m."""
    return dif_combine(A, phi, block_m=block_m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "mode", "kind", "lr", "b1", "b2", "eps", "weight_decay", "beta",
    "block_m", "interpret"))
def fused_update_flat(table, sel, ctl, scale, params, grads, mu=None,
                      nu=None, *, mode="atc", kind="adam", lr, b1=0.9,
                      b2=0.999, eps=1e-8, weight_decay=0.0, beta=0.9,
                      block_m=512, interpret=False):
    """Jit'd combine-then-update over one pre-packed (K, M) dtype group —
    the per-group entry of the one-pass contract (see dif_combine.py); the
    arbitrary-pytree driver is :func:`repro.core.fused.make_fused_outer`."""
    return fused_combine_update(
        table, sel, ctl, scale, params, grads, mu, nu, mode=mode, kind=kind,
        lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay, beta=beta,
        block_m=block_m, interpret=interpret)


def combine_tree(A: jax.Array, phi: PyTree, *, block_m: int = 512,
                 interpret: bool = False) -> PyTree:
    """Combine every leaf (leading axis = agents) of an arbitrary pytree."""
    from repro.core.diffusion import make_pallas_combine

    return make_pallas_combine(A, block_m=block_m, interpret=interpret)(phi)
