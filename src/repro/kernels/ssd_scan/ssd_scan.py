"""Pallas TPU kernel for the Mamba2 SSD chunked scan [arXiv:2405.21060].

State-space duality splits the selective-scan recurrence into

  intra-chunk:  Y₁ = (C Bᵀ ⊙ decay-mask) X         — quadratic in the chunk,
                                                      three MXU matmuls
  inter-chunk:  hₜ recurrence at chunk granularity  — carried in VMEM scratch

Grid: (B, H, L/chunk) with the chunk index minor-most, so the TPU iterates
chunks sequentially per (batch, head) and the (P, N) state lives in VMEM
scratch across that loop — the recurrence never round-trips HBM.  This is
the TPU adaptation of the paper's GPU algorithm: chunk=128/256 and N=128
make every contraction (chunk×N · N×chunk, chunk×chunk · chunk×P,
chunk×N ⊗ chunk×P) systolic-array-shaped, instead of relying on warp
shuffles for the within-chunk scan.

Inputs are pre-expanded to per-head layout:
  x (B,L,H,P)  dt (B,L,H)  A (H,1)  Bm/Cm (B,L,H,N)
Outputs: y (B,L,H,P), final state (B,H,P,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                s_scr, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)               # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)                # (c,)
    A = a_ref[0, 0].astype(jnp.float32)                     # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)              # (c, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)              # (c, N)

    dA = dt * A                                             # (c,) ≤ 0
    seg = jnp.cumsum(dA)                                    # (c,)
    # intra-chunk: M[q, k] = C_q·B_k · exp(seg_q − seg_k) · dt_k  (k ≤ q)
    li = seg[:, None] - seg[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(kj <= qi, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (c, c)
    M = cb * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())))     # (c, P)
    # inter-chunk: contribution of the entering state
    state = s_scr[...]                                      # (P, N)
    y += jnp.exp(seg)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())))                # (c, P)
    # state update: s' = exp(Σ dA) s + Σ_k exp(seg_end − seg_k) dt_k x_k B_kᵀ
    w = jnp.exp(seg[-1] - seg) * dt                         # (c,)
    s_new = (jnp.exp(seg[-1]) * state
             + jax.lax.dot_general(x * w[:, None], Bm,
                                   (((0,), (0,)), ((), ()))))  # (P, N)
    s_scr[...] = s_new
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        state_ref[0, 0] = s_new.astype(state_ref.dtype)


def ssd_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B,L,H,P); dt: (B,L,H); A: (H,); Bm/Cm: (B,L,H,N) (head-expanded).
    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, P = x.shape
    N = Bm.shape[-1]
    if L % chunk:
        raise ValueError(
            f"ssd_scan_pallas needs the sequence length to be a multiple "
            f"of the chunk: L={L} % chunk={chunk} = {L % chunk} — pad the "
            f"sequence or pick a chunk dividing it")
    nc = L // chunk
    A2 = A.reshape(H, 1)
    grid = (B, H, nc)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A2, Bm, Cm)
    return y, state
