"""Public SSD op: group→head expansion + Pallas call, jit'd."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bg, Cg, *, chunk: int = 128, interpret: bool = False):
    """Model-facing layout: Bg/Cg are (B, L, G, N) group projections; they
    are broadcast to heads here.  Returns (y (B,L,H,P), state (B,H,P,N))."""
    H = x.shape[2]
    G = Bg.shape[2]
    Bm = jnp.repeat(Bg, H // G, axis=2)
    Cm = jnp.repeat(Cg, H // G, axis=2)
    return ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
