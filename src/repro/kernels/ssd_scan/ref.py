"""Pure-jnp oracle: the naive per-step SSM recurrence (independent of the
chunked formulation, so it cross-checks the SSD math itself):

  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ        y_t = C_t · h_t
"""
import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """x: (B,L,H,P); dt: (B,L,H); A: (H,); Bm/Cm: (B,L,H,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N)) in float32."""
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    B, L, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P) (B,H) (B,H,N) (B,H,N)
        decay = jnp.exp(dtt * A)[..., None, None]   # (B,H,1,1)
        upd = dtt[..., None, None] * jnp.einsum("bhp,bhn->bhpn", xt, bt)
        h = h * decay + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    hT, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), hT
