"""Version-adaptive JAX wrappers.

The repo supports jax 0.4.x (tested on 0.4.37) and jax >= 0.5.  The two
lines differ in exactly the APIs the sharded combine path needs:

=====================  ==============================  =========================
capability             jax 0.4.x                       jax >= 0.5
=====================  ==============================  =========================
shard_map              ``jax.experimental.shard_map    ``jax.shard_map(...,
                       .shard_map(..., check_rep=,     axis_names=, check_vma=)``
                       auto=frozenset)``
AbstractMesh           ``AbstractMesh(((name, size),   ``AbstractMesh(sizes,
                       ...))`` — pair tuples           names)`` — parallel tuples
jax.make_mesh          no ``axis_types`` kwarg         ``axis_types`` kwarg
=====================  ==============================  =========================

Everything that touches one of these goes through this module so the rest
of the codebase is version-agnostic.  All wrappers are thin: they resolve
the API shape once (cheap feature probes, no version-string parsing beyond
the exported ``JAX_VERSION`` convenience) and delegate.
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Sequence

import jax

__all__ = [
    "JAX_VERSION",
    "shard_map",
    "abstract_mesh",
    "make_mesh",
    "mesh_axis_sizes",
    "cost_analysis",
    "tree_map",
    "tree_leaves",
    "tree_structure",
    "tree_flatten",
    "tree_unflatten",
]


def _parse_version(v: str) -> tuple[int, ...]:
    parts = []
    for p in v.split("."):
        digits = "".join(ch for ch in p if ch.isdigit())
        if not digits:
            break
        parts.append(int(digits))
    return tuple(parts) or (0,)


JAX_VERSION: tuple[int, ...] = _parse_version(jax.__version__)


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

def shard_map(f: Callable, mesh: Any, in_specs: Any, out_specs: Any, *,
              axis_names: Iterable[str] | None = None,
              check: bool = False) -> Callable:
    """Partial-manual shard_map over ``axis_names`` (all mesh axes if None).

    ``check`` maps to ``check_vma`` (new API) / ``check_rep`` (old API).
    Axes not in ``axis_names`` stay automatic: on the old API they are
    passed through ``auto=``, on the new API they are simply omitted from
    ``axis_names``.
    """
    manual = (frozenset(axis_names) if axis_names is not None
              else frozenset(mesh.axis_names))
    if hasattr(jax, "shard_map"):                       # jax >= 0.5
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map  # 0.4.x
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check, auto=auto)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------

def abstract_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> Any:
    """``AbstractMesh`` for both constructor generations.

    jax >= 0.5 takes parallel ``(sizes, names)`` tuples; jax 0.4.x takes a
    single tuple of ``(name, size)`` pairs.
    """
    from jax.sharding import AbstractMesh
    axis_shapes = tuple(int(s) for s in axis_shapes)
    axis_names = tuple(axis_names)
    try:
        return AbstractMesh(axis_shapes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs: Any) -> Any:
    """``jax.make_mesh`` that tolerates the missing ``axis_types`` kwarg on
    jax 0.4.x (where every axis is implicitly automatic anyway)."""
    axis_shapes = tuple(int(s) for s in axis_shapes)
    axis_names = tuple(axis_names)
    if "axis_types" not in kwargs and hasattr(jax.sharding, "AxisType"):
        kwargs["axis_types"] = (
            (jax.sharding.AxisType.Auto,) * len(axis_names))
    try:
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)
    except TypeError:
        kwargs.pop("axis_types", None)
        return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def mesh_axis_sizes(mesh: Any) -> dict[str, int]:
    """{axis name: size} for ``Mesh`` and ``AbstractMesh`` alike."""
    if hasattr(mesh, "axis_sizes"):
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    if hasattr(mesh, "shape_tuple"):
        return {name: int(size) for name, size in mesh.shape_tuple}
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def cost_analysis(compiled: Any) -> dict:
    """``compiled.cost_analysis()`` normalized across versions: newer jax
    returns a flat dict, 0.4.x returns a one-element list of dicts."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


# ---------------------------------------------------------------------------
# Tree utilities (jax.tree module appeared mid-0.4.x; fall back to tree_util)
# ---------------------------------------------------------------------------

def _tree_api(name: str) -> Callable:
    tree_mod = getattr(jax, "tree", None)
    if tree_mod is not None and hasattr(tree_mod, name):
        return getattr(tree_mod, name)
    return getattr(jax.tree_util, f"tree_{name}")


tree_map = _tree_api("map")
tree_leaves = _tree_api("leaves")
tree_structure = _tree_api("structure")
tree_flatten = _tree_api("flatten")
tree_unflatten = _tree_api("unflatten")
