"""qwen2-7b [arXiv:2407.10671] — dense GQA decoder with QKV bias.

28 layers, d_model=3584, 28 heads (GQA kv=4, head_dim=128), d_ff=18944,
vocab=152064.  28 heads ∤ 16-wide model axis and RoPE occupies head_dim →
attention replicated across TP; MLP + vocab carry tensor parallelism.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_shard="none",
    placement="data",
    meta_mode="maml",
    outer_optimizer="adam",
    source="arXiv:2407.10671",
)
