"""The paper's own classification model (§4.2, App. D.3): the Finn et al.
2017 conv net (per Vinyals et al. 2016), max-pooling variant for Omniglot.
Offline surrogate: synthetic few-shot episodes (data/fewshot.py) on 14×14
images, 2 conv blocks + linear head; 5-way 1-shot, α=0.4, meta-batch 16.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="omniglot-cnn",
    arch_type="cnn",
    num_layers=2,          # conv blocks
    d_model=32,            # conv channels
    num_heads=1, num_kv_heads=1, head_dim=1,
    d_ff=0,
    vocab_size=5,          # n_way classes
    inner_lr=0.4,
    inner_steps=1,
    meta_tasks=4,
    topology="paper",
    outer_optimizer="adam",
    outer_lr=1e-3,
    meta_mode="maml",
    remat=False,
    dtype="float32",
    source="Dif-MAML §4.2 / Finn et al. 2017",
)
