"""Architecture + run configuration.

Every assigned architecture has a module ``repro/configs/<id>.py`` exposing
``CONFIG: ArchConfig`` with the exact published dimensions (source cited in
its docstring).  ``ArchConfig.reduced()`` produces the CPU smoke-test
variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""
from __future__ import annotations

import contextlib
import dataclasses
import importlib
from typing import Any, Iterator

VOCAB_PAD = 256


def _pad(v: int, m: int = VOCAB_PAD) -> int:
    return (v + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524_288, 1, "decode"),
}

# Names of the shapes that ship with the repo: run-local registrations
# (register_input_shape / input_shape_scope) may never displace these.
_BUILTIN_SHAPES = frozenset(INPUT_SHAPES)


def register_input_shape(shape: InputShape, *,
                         override: bool = False) -> InputShape:
    """Register a run-local :class:`InputShape` under ``shape.name``.

    The registry is process-global (builders resolve shapes by name), so
    uncoordinated writes — the old ``INPUT_SHAPES[name] = ...`` idiom —
    leak state between in-process callers: a test or serving tier that
    registered ``serve_adapt`` once would silently serve a stale geometry
    to the next caller.  This helper makes collisions loud: re-registering
    an existing name raises unless ``override=True`` (same-value
    re-registration is an idempotent no-op), and the built-in shapes can
    never be displaced.  Prefer :func:`input_shape_scope` for callers with
    a bounded lifetime (tests, benchmarks, one serve session).
    """
    existing = INPUT_SHAPES.get(shape.name)
    if existing == shape:
        return shape
    if existing is not None:
        if shape.name in _BUILTIN_SHAPES:
            raise ValueError(
                f"input shape {shape.name!r} is built in ({existing}) and "
                f"cannot be overridden; register under a different name")
        if not override:
            raise ValueError(
                f"input shape {shape.name!r} is already registered as "
                f"{existing}; pass override=True to replace it or use "
                f"input_shape_scope for a scoped registration")
    INPUT_SHAPES[shape.name] = shape
    return shape


@contextlib.contextmanager
def input_shape_scope(shape: InputShape) -> Iterator[InputShape]:
    """Scoped registration: ``with input_shape_scope(shape):`` registers the
    shape on entry and restores the previous registry state on exit (the
    prior entry comes back if one existed, otherwise the name is removed) —
    repeated in-process calls (tests, benchmarks, the serving tier) cannot
    leak geometry into each other."""
    if shape.name in _BUILTIN_SHAPES and INPUT_SHAPES[shape.name] != shape:
        raise ValueError(
            f"input shape {shape.name!r} is built in and cannot be "
            f"shadowed; pick a different name")
    prev = INPUT_SHAPES.get(shape.name)
    INPUT_SHAPES[shape.name] = shape
    try:
        yield shape
    finally:
        if prev is None:
            INPUT_SHAPES.pop(shape.name, None)
        else:
            INPUT_SHAPES[shape.name] = prev


def resolve_input_shape(shape: InputShape | str) -> InputShape:
    """Resolve a shape name through the registry, or pass an
    :class:`InputShape` through unchanged — builders accept either, so
    one-shot geometries need not touch the global registry at all."""
    if isinstance(shape, InputShape):
        return shape
    try:
        return INPUT_SHAPES[shape]
    except KeyError:
        raise KeyError(
            f"unknown input shape {shape!r}: registered shapes are "
            f"{sorted(INPUT_SHAPES)} (register_input_shape / "
            f"input_shape_scope add run-local ones)") from None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio | mlp | cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    source: str = ""                # citation

    # --- attention options -------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int | None = None
    use_rope: bool = True
    attn_q_chunk: int | None = 512   # flash-style query chunking (None = full)
    # MLA (DeepSeek)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- mlp / moe ----------------------------------------------------------
    mlp_act: str = "swiglu"         # swiglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int | None = None     # per-expert hidden (defaults to d_ff)
    moe_every: int = 1              # MoE FFN on layers where (l % moe_every == moe_offset)
    moe_offset: int = 0
    first_dense_layers: int = 0     # leading dense layers before MoE (deepseek)
    moe_capacity_factor: float = 1.25
    moe_router_dtype: str = "float32"
    moe_dispatch: str = "sorted"    # sorted | einsum | auto (see layers.moe_apply)

    # --- ssm / hybrid --------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_groups: int = 1
    ssm_chunk: int = 256
    attn_every: int = 0             # hybrid: 1 attention layer per `attn_every` layers

    # --- multimodal / enc-dec ------------------------------------------------
    encoder_layers: int = 0
    encoder_frames: int = 0         # audio stub frontend sequence length
    cross_attn_every: int = 0       # vlm: every n-th layer is cross-attention
    num_patches: int = 0            # vlm stub frontend patches

    # --- meta-learning (Dif-MAML) -------------------------------------------
    placement: str = "data"         # legacy-mesh agent placement: data | pod
                                    # (ignored on meshes with an 'agent' axis)
    meta_mode: str = "maml"         # maml | fomaml | reptile
    meta_tasks: int = 2             # tasks per agent per step
    inner_lr: float = 1e-2
    inner_steps: int = 1
    topology: str = "ring"
    combine: str = "dense"
    outer_optimizer: str = "adam"
    outer_lr: float = 1e-3
    hvp_subsample: float = 1.0
    inner_freeze: str = ""          # param subtree frozen in the inner loop
                                    # (ANIL-style, e.g. "encoder")
    remat: bool = True
    remat_span: int = 1     # layers per checkpoint region (memory knob):
                            # span k saves 1/k of the per-layer residuals at
                            # the cost of re-running ≤k layers in backward

    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    outer_dtype: str = ""    # params/grads storage for the outer loop; ""
                             # inherits dtype.  Adam moments stay fp32 either
                             # way (optim/optimizers.py initialises them f32).
    combine_dtype: str = ""  # combine wire format; "" resolves via
                             # diffusion.resolve_combine_dtype (bf16 outer →
                             # bf16 wire).  "float32" is the escape hatch.
    attn_shard: str = "heads"       # heads | head_dim | none  (TP strategy)
    tie_embeddings: bool = False

    # -------------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return _pad(self.vocab_size)

    @property
    def moe_hidden(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def num_agents(self, mesh_axes: dict[str, int]) -> int:
        """Agent count given mesh axis sizes (e.g. {'pod':2,'data':16,...}).

        A first-class ``agent`` mesh axis wins outright (``placement`` is
        a legacy-mesh concept — see launch/mesh.py's mesh-axis contract)."""
        if "agent" in mesh_axes:
            return mesh_axes["agent"]
        if self.placement == "pod":
            return mesh_axes.get("pod", 1)
        K = mesh_axes.get("data", 1) * (
            mesh_axes.get("pod", 1) if "pod" in mesh_axes else 1)
        return K

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims."""
        kw: dict[str, Any] = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            remat=False,
        )
        if self.num_experts:
            kw.update(num_experts=min(self.num_experts, 4),
                      num_shared_experts=min(self.num_shared_experts, 1),
                      experts_per_token=min(self.experts_per_token, 2),
                      moe_d_ff=min(self.moe_hidden, 128),
                      first_dense_layers=min(self.first_dense_layers, 1))
        if self.use_mla:
            kw.update(kv_lora_rank=64, qk_rope_dim=16, qk_nope_dim=32,
                      v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=32, ssm_head_dim=16, ssm_chunk=32)
        if self.attn_every:
            kw.update(num_layers=self.attn_every)  # one full hybrid period
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_frames=16)
        if self.cross_attn_every:
            kw.update(num_layers=2 * self.cross_attn_every,
                      num_patches=min(self.num_patches or 16, 16))
        if self.sliding_window:
            kw.update(sliding_window=64)
        return dataclasses.replace(self, **kw)


ASSIGNED = [
    "whisper_large_v3", "deepseek_v2_lite_16b", "qwen2_1_5b", "command_r_35b",
    "mixtral_8x22b", "jamba_1_5_large_398b", "mamba2_130m",
    "llama_3_2_vision_90b", "codeqwen1_5_7b", "qwen2_7b",
]
PAPER_OWN = ["sine_mlp", "omniglot_cnn"]


def _canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_canon(name)}")
    return mod.CONFIG


def list_archs(include_paper: bool = False) -> list[str]:
    return ASSIGNED + (PAPER_OWN if include_paper else [])
