"""deepseek-v2-lite-16b [arXiv:2405.04434] — MoE with Multi-head Latent
Attention.

27 layers, d_model=2048, 16 heads, MLA kv_lora_rank=512 (+64 rope dims),
MoE: 64 routed experts top-6 + 2 shared, per-expert hidden 1408,
vocab=102400.  First layer uses a dense FFN (hidden 10944, per the
model card); the assignment's "d_ff=1408" is the per-expert hidden.
(The bracket note "2 shared+160 routed" describes DeepSeek-V2-236B; the
authoritative lite config line "MoE 64e top-6" is used.)
Outer optimizer: bf16 momentum (fp32 Adam state for 16B exceeds v5e HBM
alongside the MAML adapted copy).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,            # q/k nope dim (MLA overrides per-component dims)
    d_ff=10944,              # dense FFN (layer 0)
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    attn_shard="heads",
    placement="data",
    meta_mode="fomaml",
    outer_optimizer="momentum",
    source="arXiv:2405.04434",
)
