"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision, scaled per the
90B card] — decoder with interleaved gated cross-attention image layers.

100 layers = 20 periods of (4 self-attention + 1 gated cross-attention),
d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256.  The ViT
vision encoder + projector are STUBBED: input_specs feeds (B, 576, 8192)
projected patch embeddings; the framework implements the language decoder
that consumes them (tanh-gated cross-attn per the Llama-3.2 card).

Agent placement = 'pod' (a 90B per-agent replica + MAML adapted copy
exceeds one 16-chip mesh row).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_patches=576,
    rope_theta=500_000.0,
    attn_shard="heads",
    placement="pod",
    meta_mode="fomaml",
    outer_optimizer="sgd",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
