"""qwen2-7b-swa — beyond-paper variant: qwen2-7b with an 8k sliding window.

The assigned qwen2-7b is pure full attention, so ``long_500k`` is skipped
for it (DESIGN.md §4).  This variant swaps in sliding-window attention
(window 8192, the mechanism Qwen2 itself uses for its long-context tiers),
which bounds the decode KV cache at the window and makes the 524k-token
decode shape servable.  Benchmarked separately from the faithful config.
"""
import dataclasses

from repro.configs.qwen2_7b import CONFIG as _BASE

CONFIG = dataclasses.replace(
    _BASE,
    name="qwen2-7b-swa",
    sliding_window=8192,
    source="arXiv:2407.10671 (+SWA long-context variant)",
)
