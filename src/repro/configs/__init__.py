from repro.configs.base import ArchConfig, InputShape, INPUT_SHAPES, get_config, list_archs

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get_config", "list_archs"]
