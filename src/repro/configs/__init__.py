from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                get_config, input_shape_scope, list_archs,
                                register_input_shape, resolve_input_shape)

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "get_config",
           "input_shape_scope", "list_archs", "register_input_shape",
           "resolve_input_shape"]
