"""mixtral-8x22b [arXiv:2401.04088] — sparse MoE with sliding-window attn.

56 layers, d_model=6144, 48 heads (GQA kv=8, head_dim=128), 8 experts
top-2 with per-expert hidden 16384, vocab=32768, SWA window 4096.

Agent placement = 'pod': at 141B parameters a per-agent replica does not
fit one mesh row, so the diffusion graph spans pods (the paper's own
motivation — sparse inter-pod links carry the combine; dense intra-pod ICI
carries FSDP/TP).  On the single-pod mesh this degenerates to K=1
(centralized); the technique engages on the 2-pod mesh.
SWA makes long_500k eligible (window-bounded KV cache).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    arch_type="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    num_experts=8,
    experts_per_token=2,
    rope_theta=1_000_000.0,
    attn_shard="heads",
    placement="pod",
    meta_mode="fomaml",
    outer_optimizer="sgd",
    source="arXiv:2401.04088",
)
