"""qwen2-1.5b [arXiv:2407.10671] — dense GQA decoder with QKV bias.

28 layers, d_model=1536, 12 heads (GQA kv=2, head_dim=128), d_ff=8960,
vocab=151936.  12 heads ∤ 16-wide model axis and RoPE occupies head_dim,
so attention stays replicated across TP (attn_shard='none'); MLP + vocab
carry the tensor parallelism.  (Hillclimb candidate: head padding 12→16.)
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    arch_type="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_shard="none",
    placement="data",
    meta_mode="maml",
    outer_optimizer="adam",
    source="arXiv:2407.10671",
)
