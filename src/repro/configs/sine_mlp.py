"""The paper's own regression model (§4.1, App. D.1): an MLP with 2 hidden
layers of 40 ReLU units, MSE loss, 10-shot sine-wave tasks, α=0.01,
Adam μ=0.001 (SGD variant μ=0.005), K=6 agents on the Fig. 2a graph.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="sine-mlp",
    arch_type="mlp",
    num_layers=2,          # hidden layers
    d_model=40,            # hidden width
    num_heads=1, num_kv_heads=1, head_dim=1,
    d_ff=0,
    vocab_size=1,          # regression: 1-d input / 1-d output
    inner_lr=0.01,
    inner_steps=1,
    meta_tasks=5,
    topology="paper",
    outer_optimizer="adam",
    outer_lr=1e-3,
    meta_mode="maml",
    remat=False,
    dtype="float32",
    source="Dif-MAML §4.1 / Finn et al. 2017",
)
