"""mamba2-130m [arXiv:2405.21060] — attention-free SSD (state-space duality).

24 layers, d_model=768 (d_inner=1536, 24 SSD heads of head_dim 64),
ssm_state=128, vocab=50280.  No attention, no FFN — each block is a single
Mamba2 mixer (the published architecture).  long_500k eligible: O(1)
recurrent state per layer.

num_heads/d_ff are unused placeholders (attention-free).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,      # unused (attention-free)
    num_kv_heads=12,   # unused
    head_dim=64,       # unused
    d_ff=0,            # no FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=256,
    attn_shard="none",
    placement="data",
    meta_mode="maml",
    outer_optimizer="adam",
    source="arXiv:2405.21060",
)
