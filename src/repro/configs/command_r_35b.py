"""command-r-35b [hf:CohereForAI/c4ai-command-r-v01] — dense GQA, no bias.

40 layers, d_model=8192, 64 heads (GQA kv=8, head_dim=128), d_ff=22528,
vocab=256000.  LayerNorm (Cohere-style), SiLU-gated MLP.  Outer optimizer:
SGD (35B fp32 Adam state would not fit next to the MAML adapted copy).
Note: the real model uses a parallel attention+FFN block; we use the
standard sequential pre-norm block (recorded as an adaptation in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    arch_type="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    norm="layernorm",
    rope_theta=8_000_000.0,
    attn_shard="heads",
    placement="data",
    meta_mode="maml",
    outer_optimizer="sgd",
    source="hf:CohereForAI/c4ai-command-r-v01",
)
