"""whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio transformer.

32 enc + 32 dec layers, d_model=1280, 20 heads (MHA, kv=20), d_ff=5120,
vocab=51866.  Mel-spectrogram + conv frontend is STUBBED: input_specs feeds
(B, 1500, 1280) precomputed frame embeddings (1500 = 30 s at 50 Hz).
LayerNorm + GELU + absolute sinusoidal positions (no RoPE) per the paper.
Attention stays replicated across TP (HC3: head_dim sharding all-reduced
the (S,T) logits every layer); MLP + vocab carry the model parallelism.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,
    encoder_layers=32,
    encoder_frames=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    norm="layernorm",
    mlp_act="gelu",
    use_rope=False,
    qkv_bias=True,
    # head_dim TP all-reduces the full (S,T) logits of every (cross-)attention
    # — measured 4.1e12 wire B/dev on train_4k vs 6.0e10 with replicated
    # attention (EXPERIMENTS HC3).  At 1.5B params replicating attention
    # weights is cheap; MLP + vocab keep the tensor parallelism.
    attn_shard="none",
    placement="data",
    meta_mode="maml",
    outer_optimizer="adam",
    source="arXiv:2212.04356",
)
