"""jamba-1.5-large-398b [arXiv:2403.19887] — hybrid Mamba+attention MoE.

72 layers in 9 periods of 8 (1 attention : 7 Mamba, per the Jamba ratio),
MoE (16 experts, top-2) on every other layer, d_model=8192, 64 heads
(GQA kv=8), d_ff=24576, vocab=65536.  Mamba mixers use the Mamba2/SSD
formulation (state 128, head_dim 64) — a TPU adaptation recorded in
DESIGN.md (chunked SSD maps to MXU matmuls; Mamba1's selective scan does
not).

Agent placement = 'pod' (398B): diffusion graph spans pods; intra-pod
FSDP×TP.  long_500k eligible: only 9/72 layers carry a KV cache, Mamba
layers carry O(1) state.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    arch_type="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    attn_every=8,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    use_rope=True,
    attn_shard="heads",
    placement="pod",
    meta_mode="fomaml",
    outer_optimizer="sgd",
    source="arXiv:2403.19887",
)
