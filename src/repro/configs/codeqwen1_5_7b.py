"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B] — qwen1.5-architecture dense MHA.

32 layers, d_model=4096, 32 heads (kv=32 — full MHA), d_ff=13440,
vocab=92416, QKV bias (qwen1.5 lineage), RoPE theta 1M (64k context).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_shard="heads",
    placement="data",
    meta_mode="maml",
    outer_optimizer="adam",
    source="hf:Qwen/CodeQwen1.5-7B",
)
