"""Logical-axis → mesh-axis sharding rules.

Each parameter / cache / batch leaf carries a tuple of logical axis names
(from models/init.py Specs).  ``spec_for`` greedily assigns mesh axes to
dims in rule-priority order, skipping any assignment where the mesh axes do
not evenly divide the dim or were already used by another dim of the same
leaf.  This yields valid PartitionSpecs for *every* architecture (head
counts like 12/20/28 that don't divide the 16-wide model axis simply fall
through to the next candidate or stay replicated).

A *candidate* is a tuple of mesh-axis names — the dim is sharded jointly
over all of them (e.g. ``('pod', 'data')`` shards one dim 32-way).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import mesh_axis_sizes
from repro.configs.base import ArchConfig

PyTree = Any
Candidate = tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Rules:
    table: tuple[tuple[str, tuple[Candidate, ...]], ...]
    priority: tuple[str, ...]

    def candidates(self, logical: str) -> tuple[Candidate, ...]:
        for k, v in self.table:
            if k == logical:
                return v
        return ()


def rules_for(cfg: ArchConfig, mesh: Mesh, kind: str = "train") -> Rules:
    """Rule set for an architecture on a mesh.  kind: 'train' | 'decode'.

    On a mesh with a first-class ``agent`` axis (``launch/mesh.py``'s
    ``make_production_mesh(agents=K)`` family) the logical ``agent`` axis
    maps 1:1 onto it and ``cfg.placement`` is moot: ``data`` (when present)
    is purely intra-agent FSDP/batch parallelism and ``model`` is TP, so
    each agent's K-th slice of the parameter stack is itself TP/FSDP-
    sharded.  Legacy meshes keep the placement-driven rules (agents on
    ``pod`` or tiling ``(pod, data)``)."""
    multi_pod = "pod" in mesh.axis_names
    agent_mesh = "agent" in mesh.axis_names
    pod_placed = cfg.placement == "pod"

    if kind == "train":
        if agent_mesh:
            has_data = "data" in mesh.axis_names
            # agents = the dedicated axis; 'data' (if any) does FSDP +
            # batch *within* each agent, exactly like the pod-placed rules
            agent: tuple[Candidate, ...] = (("agent",),)
            batch: tuple[Candidate, ...] = (
                (("agent", "data"), ("agent",)) if has_data
                else (("agent",),))
            fsdp: tuple[Candidate, ...] = (("data",),) if has_data else ()
            experts: tuple[Candidate, ...] = (
                (("data",), ("model",)) if has_data else (("model",),))
        elif pod_placed:
            # agents = pods; 'data' axis does FSDP + batch within each agent
            agent: tuple[Candidate, ...] = ((("pod",),) if multi_pod else ())
            # the global batch dim of inputs: agent-major then data within
            batch: tuple[Candidate, ...] = (
                (("pod", "data"), ("data",)) if multi_pod else (("data",),))
            fsdp: tuple[Candidate, ...] = (("data",),)
            experts: tuple[Candidate, ...] = (("data",), ("model",))
        else:
            # agents tile the whole data-parallel extent; the input batch
            # dim is agent-major and carries the same sharding
            agent = ((("pod", "data"),) if multi_pod else (("data",),))
            batch = agent
            fsdp = ()
            experts = (("model",),)
        attn_heads: tuple[Candidate, ...] = (
            (("model",),) if cfg.attn_shard == "heads" else ())
        attn_hd: tuple[Candidate, ...] = (
            (("model",),) if cfg.attn_shard == "head_dim" else ())
        table = (
            ("agent", agent),
            ("batch", batch),
            ("vocab", (("model",),)),
            ("ffn", (("model",),)),
            ("heads", attn_heads),
            ("head_dim", attn_hd),
            ("kv_lora", (("model",),)),
            ("ssm_dim", (("model",),)),
            ("experts", experts),
            ("embed", fsdp),
        )
        priority = ("agent", "vocab", "ffn", "experts", "heads", "head_dim",
                    "kv_lora", "ssm_dim", "batch", "embed")
        return Rules(table, priority)

    # ---- decode / serving ----------------------------------------------------
    batch = (("pod", "data"), ("data",)) if multi_pod else (("data",),)
    table = (
        ("batch", batch),
        # long-context KV caches (batch too small to shard) fall back to
        # sharding the sequence dim of the cache over the data axis
        ("seq", (("data",), ("pod",)) if multi_pod else (("data",),)),
        ("vocab", (("model",),)),
        ("ffn", (("model",),)),
        ("heads", (("model",),) if cfg.attn_shard == "heads" else ()),
        # decode always shards head_dim: the contraction's all-reduce is a
        # (B,KV,1,C) sliver, and an unsharded KV cache would replicate
        # model-axis-wide (measured 6.8 → 107 GiB/dev on whisper decode)
        ("head_dim", (("model",),)),
        ("kv_heads", ()),
        ("kv_lora", (("model",),)),
        ("ssm_dim", (("model",),)),
        ("experts", (("model",),)),
        ("embed", ()),
    )
    priority = ("batch", "vocab", "ffn", "experts", "heads", "kv_heads",
                "head_dim", "kv_lora", "ssm_dim", "seq", "embed")
    return Rules(table, priority)


# Mesh/AbstractMesh axis sizes across JAX versions (kept under the old name
# because launch/steps.py imports it).
_axis_sizes = mesh_axis_sizes


def spec_for(axes: Sequence[str | None], shape: Sequence[int], rules: Rules,
             mesh: Mesh) -> P:
    """Greedy, divisibility-checked PartitionSpec for one leaf."""
    mesh_sizes = _axis_sizes(mesh)
    assignment: dict[int, Any] = {}
    used: set[str] = set()

    order = sorted(
        range(len(axes)),
        key=lambda i: (rules.priority.index(axes[i])
                       if axes[i] in rules.priority else len(rules.priority)),
    )
    for i in order:
        name = axes[i]
        if name is None:
            continue
        for cand in rules.candidates(name):
            if any(a in used or a not in mesh_sizes for a in cand):
                continue
            size = 1
            for a in cand:
                size *= mesh_sizes[a]
            if shape[i] == 0 or shape[i] % size != 0:
                continue
            assignment[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
            break
    return P(*[assignment.get(i) for i in range(len(axes))])


def tree_shardings(axes_tree: PyTree, shape_tree: PyTree, rules: Rules,
                   mesh: Mesh) -> PyTree:
    """NamedSharding tree matching an axes tree + shape/array tree."""

    def leaf(ax, shaped):
        shape = shaped.shape if hasattr(shaped, "shape") else tuple(shaped)
        return NamedSharding(mesh, spec_for(ax, shape, rules, mesh))

    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)
    return jax.tree.map(leaf, axes_tree, shape_tree, is_leaf=is_axes)
