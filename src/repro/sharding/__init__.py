from repro.sharding.rules import Rules, rules_for, spec_for, tree_shardings

__all__ = ["Rules", "rules_for", "spec_for", "tree_shardings"]
