"""The serving tier: batched adaptation + cached adapted state + scanned
decode.

``launch/serve.py`` is a thin CLI over this module.  The engine owns the
three serving-cost levers:

batched adaptation
    N concurrent user episodes adapt in ONE vmapped+jitted
    ``inner_adapt`` dispatch (``EvalHarness.adapt_states`` — the same
    primitive eval jits) instead of N sequential per-request calls.
    Request counts are padded up to a small set of compile *buckets* so
    mixed batch sizes reuse compiled programs instead of retracing.

adapted-state cache
    Recurring tasks (same ``TaskKey``: source fingerprint × domain ×
    adapt hyperparams) skip re-adaptation entirely — the cache
    reconstructs ``w + δ`` from a host-resident low-rank delta
    (``serve/cache.py``, ``serve/lowrank.py``).

scanned decode
    Decode is two jitted ``lax.scan`` programs — a teacher-forced
    *prefill* over the prompt and a sampling *decode* over generated
    positions — so the steady state is dispatch-free per token batch (no
    per-token Python dispatch or ``np.asarray`` host sync), and the two
    phases time (and report tok/s) separately.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, InputShape
from repro.data.episodes import Episode
from repro.eval.harness import EvalHarness
from repro.launch import steps as S
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import build_model
from repro.serve.cache import AdaptedStateCache, TaskKey, task_key

PyTree = Any

__all__ = ["AdaptRequest", "ServeEngine"]


@dataclasses.dataclass
class AdaptRequest:
    """One user's adaptation request: a support episode to adapt on, plus
    the cache coordinate (``key=None`` opts out of caching)."""
    support: dict
    key: TaskKey | None = None


def _percentiles(xs: Sequence[float]) -> dict:
    if not xs:
        return {}
    a = np.asarray(xs, dtype=np.float64)
    return {"p50_us": float(np.percentile(a, 50) * 1e6),
            "p99_us": float(np.percentile(a, 99) * 1e6),
            "mean_us": float(a.mean() * 1e6),
            "n": len(xs)}


class ServeEngine:
    """Adaptation-as-a-service over one launch model.

    Geometry (``batch`` decode sequences of ``prompt_len + gen`` tokens)
    is fixed per engine — the decode scans compile once.  ``buckets``
    are the adapt-batch compile sizes; a request batch pads up to the
    next bucket (and chunks above the largest), so any request count is
    served by ``len(buckets)`` compiled programs.
    """

    def __init__(self, cfg: ArchConfig, *, prompt_len: int, gen: int,
                 batch: int, mesh=None, adapt_steps: int | None = None,
                 inner_lr: float | None = None, temperature: float = 0.0,
                 cache_capacity: int = 64, rank: int = 8, tol: float = 0.3,
                 buckets: tuple[int, ...] = (1, 2, 4, 8, 16),
                 dtype=None):
        if prompt_len < 1 or gen < 1:
            raise ValueError("prompt_len and gen must be >= 1")
        self.cfg = cfg
        self.prompt_len = prompt_len
        self.gen = gen
        self.batch = batch
        self.total = prompt_len + gen
        self.temperature = temperature
        self.buckets = tuple(sorted(set(buckets)))
        self.dtype = dtype if dtype is not None else S.DTYPES[cfg.dtype]
        self.mesh = mesh if mesh is not None else make_host_mesh()
        self.inner_lr = float(cfg.inner_lr if inner_lr is None else inner_lr)
        self.adapt_steps = int(cfg.inner_steps if adapt_steps is None
                               else adapt_steps)

        with self.mesh:
            self.model = build_model(cfg)
            # a one-shot InputShape handed straight to the builder — the
            # engine never touches the global INPUT_SHAPES registry
            shape = InputShape("serve_adapt", self.total, batch, "decode")
            self.bundle = S.build_serve(cfg, self.mesh, shape)
        self.harness = EvalHarness(self.model.loss_fn, self.inner_lr,
                                   self.adapt_steps)
        self.cache = AdaptedStateCache(capacity=cache_capacity, rank=rank,
                                       tol=tol)
        self.params: PyTree | None = None
        self._adapt_log: list[dict] = []
        self._decode_log: list[dict] = []
        self._build_decode_fns()

    # -- params ---------------------------------------------------------------

    def load_params(self, params: PyTree) -> None:
        """Install the launch model (checkpoint centroid or fresh init)
        all residents adapt from.  Invalidates nothing: deltas key on the
        task, so swap params only together with a fresh cache."""
        self.params = params

    def _require_params(self) -> PyTree:
        if self.params is None:
            raise RuntimeError(
                "no launch model loaded: call load_params() first")
        return self.params

    # -- batched adaptation ---------------------------------------------------

    def signature(self, source: Any, domain: int) -> TaskKey:
        """Cache key for ``domain`` of ``source`` under this engine's
        adapt hyperparameters."""
        return task_key(source, domain, self.adapt_steps, self.inner_lr)

    def requests_from_episode(self, source: Any, ep: Episode
                              ) -> list[AdaptRequest]:
        """Split an ``eval_sample`` episode (task-leading leaves) into one
        keyed request per task."""
        n = jax.tree.leaves(ep.support)[0].shape[0]
        doms = np.asarray(ep.domains)
        return [AdaptRequest({k: v[i] for k, v in ep.support.items()},
                             self.signature(source, int(doms[i])))
                for i in range(n)]

    def _bucket(self, n: int) -> int:
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _stack_supports(self, supports: list[dict], pad_to: int) -> dict:
        n = len(supports)
        rows = supports + [supports[0]] * (pad_to - n)
        stacked = {k: jnp.stack([jnp.asarray(r[k]) for r in rows])
                   for k in supports[0]}
        tb = jax.tree.leaves(stacked)[0].shape[1]
        stacked.update(S.modality_extras(self.cfg, (pad_to, tb), self.dtype))
        return stacked

    def adapt(self, requests: Sequence[AdaptRequest]
              ) -> tuple[list[PyTree], dict]:
        """Serve a batch of adaptation requests.

        Cache hits reconstruct from their stored delta; misses adapt in
        bucket-padded vmapped ``inner_adapt`` dispatches and enter the
        cache.  Returns per-request adapted params (request order) and a
        metrics record (hit/miss counts, bucket sizes, phase seconds).
        """
        params = self._require_params()
        results: list[PyTree | None] = [None] * len(requests)

        with self.mesh:
            t0 = time.perf_counter()
            miss_idx = []
            for i, req in enumerate(requests):
                hit = (self.cache.lookup(req.key, params)
                       if req.key is not None else None)
                if hit is None:
                    miss_idx.append(i)
                else:
                    results[i] = hit
            hit_s = time.perf_counter() - t0

            buckets_used = []
            t0 = time.perf_counter()
            cap = self.buckets[-1]
            for lo in range(0, len(miss_idx), cap):
                chunk = miss_idx[lo: lo + cap]
                b = self._bucket(len(chunk))
                buckets_used.append(b)
                stacked = self._stack_supports(
                    [requests[i].support for i in chunk], b)
                adapted = jax.block_until_ready(
                    self.harness.adapt_states(params, stacked))
                for j, i in enumerate(chunk):
                    one = jax.tree.map(lambda x, j=j: x[j], adapted)
                    results[i] = one
                    if requests[i].key is not None:
                        self.cache.insert(requests[i].key, params, one)
            miss_s = time.perf_counter() - t0

        n_miss = len(miss_idx)
        metrics = {
            "n": len(requests),
            "hits": len(requests) - n_miss,
            "misses": n_miss,
            "buckets": buckets_used,
            "hit_s": hit_s,
            "miss_s": miss_s,
            "seconds": hit_s + miss_s,
        }
        self._adapt_log.append(metrics)
        return results, metrics  # type: ignore[return-value]

    def adapted_loss(self, adapted: Sequence[PyTree], batches: Sequence[dict]
                     ) -> np.ndarray:
        """(n,) query losses, each task's adapted params on its own batch
        — the drift probe for delta-reconstructed states."""
        tb = jax.tree.leaves(batches[0])[0].shape[0]
        stacked_p = jax.tree.map(lambda *xs: jnp.stack(xs), *adapted)
        stacked_b = {k: jnp.stack([jnp.asarray(b[k]) for b in batches])
                     for k in batches[0]}
        stacked_b.update(S.modality_extras(self.cfg, (len(batches), tb),
                                           self.dtype))
        return np.asarray(self.harness.task_loss(stacked_p, stacked_b))

    # -- scanned decode -------------------------------------------------------

    def _build_decode_fns(self) -> None:
        step_fn = self.bundle.step_fn
        B, P, G = self.batch, self.prompt_len, self.gen
        temperature = self.temperature

        def prefill(params, cache, prompt):
            # teacher-forced prompt positions 0..P-2 (logits discarded:
            # the next input is the prompt itself)
            xs = (prompt.T[: P - 1],
                  jnp.arange(P - 1, dtype=jnp.int32))

            def body(c, x):
                tok, pos = x
                _, c = step_fn(params, c, tok[:, None],
                               jnp.full((B,), pos, jnp.int32))
                return c, None

            cache, _ = jax.lax.scan(body, cache, xs)
            return cache

        def decode(params, cache, tok0, key):
            # positions P-1..P+G-2: feed the current token, sample the
            # next — G sampled tokens, zero host syncs inside the scan
            def body(carry, pos):
                c, tok = carry
                logits, c = step_fn(params, c, tok[:, None],
                                    jnp.full((B,), pos, jnp.int32))
                if temperature > 0:
                    k = jax.random.fold_in(key, pos)
                    nxt = jax.random.categorical(
                        k, logits[:, 0] / temperature, axis=-1)
                else:
                    nxt = jnp.argmax(logits[:, 0], axis=-1)
                nxt = nxt.astype(jnp.int32)
                return (c, nxt), nxt

            (cache, _), out = jax.lax.scan(
                body, (cache, tok0),
                jnp.arange(P - 1, P - 1 + G, dtype=jnp.int32))
            return out.T, cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def _encoder_state(self, params: PyTree):
        cfg, B = self.cfg, self.batch
        if cfg.arch_type == "audio":
            frames = jnp.zeros((B, cfg.encoder_frames, cfg.d_model),
                               self.dtype)
            return self.model.encode(params, frames)
        if cfg.arch_type == "vlm":
            patches = jnp.zeros((B, cfg.num_patches, cfg.d_model),
                                self.dtype)
            return patches @ params["vision_proj"]
        return None

    def decode(self, params: PyTree, prompt: Any, seed: int = 0
               ) -> tuple[np.ndarray, dict]:
        """Generate ``gen`` tokens per sequence from an adapted model.

        ``prompt`` is ``(batch, prompt_len)`` int tokens.  Returns
        ``(batch, prompt_len + gen)`` tokens and per-phase metrics —
        prompt (prefill) and decode are timed separately, each a single
        jitted dispatch.
        """
        prompt = jnp.asarray(prompt, jnp.int32)
        if prompt.shape != (self.batch, self.prompt_len):
            raise ValueError(
                f"prompt shape {prompt.shape} != "
                f"{(self.batch, self.prompt_len)}")
        with self.mesh:
            cache = self.model.init_cache(
                self.batch, self.total, self.dtype, params=params,
                enc=self._encoder_state(params))
            t0 = time.perf_counter()
            cache = jax.block_until_ready(
                self._prefill(params, cache, prompt))
            prefill_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            out, _ = self._decode(params, cache, prompt[:, -1],
                                  jax.random.key(seed))
            out = jax.block_until_ready(out)
            decode_s = time.perf_counter() - t0

        B, P, G = self.batch, self.prompt_len, self.gen
        metrics = {
            "prefill_s": prefill_s,
            "decode_s": decode_s,
            # prefill processes P-1 prompt tokens, decode emits G tokens;
            # the two phases report tok/s separately (a single combined
            # number double-charges prompt steps to generation)
            "prompt_tok_s": B * (P - 1) / prefill_s if P > 1 else 0.0,
            "decode_tok_s": B * G / decode_s,
        }
        self._decode_log.append(metrics)
        tokens = np.concatenate([np.asarray(prompt), np.asarray(out)], axis=1)
        return tokens, metrics

    # -- run log --------------------------------------------------------------

    def log_record(self) -> dict:
        """One ``kind=serve`` JSONL record: engine geometry, cache
        counters, and adapt/decode latency distributions."""
        adapt_lat = [m["seconds"] / max(m["n"], 1) for m in self._adapt_log]
        return {
            "kind": "serve",
            "arch": self.cfg.name,
            "batch": self.batch,
            "prompt_len": self.prompt_len,
            "gen": self.gen,
            "adapt_steps": self.adapt_steps,
            "inner_lr": self.inner_lr,
            "buckets": list(self.buckets),
            "cache": self.cache.stats(),
            "adapt": {
                "calls": len(self._adapt_log),
                "requests": sum(m["n"] for m in self._adapt_log),
                **_percentiles(adapt_lat),
            },
            "decode": {
                "calls": len(self._decode_log),
                "prompt_tok_s": [m["prompt_tok_s"]
                                 for m in self._decode_log[-8:]],
                "decode_tok_s": [m["decode_tok_s"]
                                 for m in self._decode_log[-8:]],
            },
        }
