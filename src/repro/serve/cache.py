"""Adapted-state cache: the recurring-user fast path.

Recurring tasks re-adapt the launch model to the *same* solution (the
generalization result of Fallah et al. 2021 that ``EvalHarness`` measures
as the recurring split), so adaptation is memoizable: key on the task
signature — source fingerprint × domain × adapt hyperparameters — and a
repeat request becomes a delta reconstruction (``lowrank.apply_delta``)
instead of an inner-loop re-adaptation.

The cache is LRU over :class:`~repro.serve.lowrank.CompressedDelta`
entries (host-resident, low-rank factored), with hit/miss/eviction
counters that the serving tier surfaces in its run log.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.serve.lowrank import CompressedDelta, compress_delta

PyTree = Any

__all__ = ["AdaptedStateCache", "TaskKey", "source_fingerprint", "task_key"]


def source_fingerprint(source: Any) -> str:
    """Deterministic identity of a task source's *distribution*.

    Two sources with the same fingerprint draw the same task universe, so
    their domain ids are interchangeable cache coordinates.  Dataclass
    sources (the ``TaskSource`` surface) fingerprint as their primitive
    field values; anything else falls back to class name + sorted
    primitive attributes.
    """
    cls = type(source).__name__
    if dataclasses.is_dataclass(source):
        items = [(f.name, getattr(source, f.name))
                 for f in dataclasses.fields(source)]
    else:
        items = sorted(vars(source).items()) if hasattr(source, "__dict__") \
            else []
    prims = [(k, v) for k, v in items
             if isinstance(v, (bool, int, float, str))]
    return cls + "(" + ",".join(f"{k}={v!r}" for k, v in prims) + ")"


@dataclasses.dataclass(frozen=True)
class TaskKey:
    """Cache key: which task, under which adaptation.

    ``source`` pins the task distribution, ``domain`` the task within it,
    and ``adapt`` the inner-loop hyperparameters ``(steps, lr)`` — the
    same domain adapted with a different lr or step count is a different
    resident state.
    """
    source: str
    domain: int
    adapt: tuple[int, float]


def task_key(source: Any, domain: int, inner_steps: int,
             inner_lr: float) -> TaskKey:
    return TaskKey(source_fingerprint(source), int(domain),
                   (int(inner_steps), float(inner_lr)))


class AdaptedStateCache:
    """LRU cache of compressed adaptation deltas.

    ``lookup(key, base)`` returns the reconstructed adapted params (and
    counts a hit) or ``None`` (a miss); ``insert(key, base, adapted)``
    compresses and stores the delta, evicting least-recently-used entries
    beyond ``capacity``.
    """

    def __init__(self, capacity: int = 64, rank: int = 8,
                 tol: float = 0.3):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.rank = rank
        self.tol = tol
        self._store: collections.OrderedDict[TaskKey, CompressedDelta] = \
            collections.OrderedDict()
        # the reconstruction add is jitted once (per tree/shape) — the
        # per-leaf eager version costs ~3 dispatches per leaf, enough to
        # erase the hit path's latency win on small models
        self._apply_fn = jax.jit(lambda base, dense: jax.tree.map(
            lambda b, d: (b.astype(jnp.float32) + d).astype(b.dtype),
            base, dense))
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key: TaskKey) -> bool:
        return key in self._store

    def lookup(self, key: TaskKey, base: PyTree) -> PyTree | None:
        entry = self._store.get(key)
        if entry is None:
            self.misses += 1
            return None
        self.hits += 1
        self._store.move_to_end(key)
        dense = jax.tree.map(lambda d: d.materialize(), entry.leaves)
        return self._apply_fn(base, dense)

    def insert(self, key: TaskKey, base: PyTree, adapted: PyTree
               ) -> CompressedDelta:
        entry = compress_delta(base, adapted, rank=self.rank, tol=self.tol)
        self._store[key] = entry
        self._store.move_to_end(key)
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1
        return entry

    def stats(self) -> dict:
        """Run-log-ready counters + residency accounting."""
        stored = sum(e.nbytes for e in self._store.values())
        dense = sum(e.dense_nbytes for e in self._store.values())
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "residents": len(self._store),
            "capacity": self.capacity,
            "rank": self.rank,
            "stored_bytes": int(stored),
            "dense_bytes": int(dense),
            "compression": float(dense / max(stored, 1)),
        }
