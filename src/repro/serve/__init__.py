"""Serving tier: batched inner-loop adaptation, an adapted-state cache
with low-rank deltas, and dispatch-free scanned decode.  See SERVING.md
for the architecture and ``launch/serve.py`` for the CLI."""
from repro.serve.cache import (AdaptedStateCache, TaskKey,
                               source_fingerprint, task_key)
from repro.serve.engine import AdaptRequest, ServeEngine
from repro.serve.lowrank import (CompressedDelta, DenseLeaf, LowRankLeaf,
                                 apply_delta, compress_delta)

__all__ = [
    "AdaptRequest", "AdaptedStateCache", "CompressedDelta", "DenseLeaf",
    "LowRankLeaf", "ServeEngine", "TaskKey", "apply_delta",
    "compress_delta", "source_fingerprint", "task_key",
]
