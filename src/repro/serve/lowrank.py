"""Low-rank storage of inner-loop adaptation deltas.

A resident serving user is an adapted launch model ``φ = w + δ`` where
``w`` is the shared checkpoint centroid and ``δ`` is the inner-loop delta
(a few SGD steps' worth of ``-α∇L`` — see ``core/maml.inner_adapt``).
Storing full ``φ`` per resident user caps residency at device/host memory
over the full parameter count; storing only ``δ`` — rank-r factored for
matrix leaves, dense for the rest — scales resident-user count by the
compression ratio, and reconstruction (``w + UV``) is a cheap add at
cache-hit time, orders of magnitude under a re-adaptation.

Compression is *fidelity-gated*: a matrix leaf is stored factored only
when the rank-r truncation keeps the relative Frobenius error of the
delta within ``tol``; otherwise that leaf falls back to dense.  The
pinned serving guarantee (delta-reconstructed params match the full
adapted params within |Δ query loss| ≤ 1e-2) therefore degrades into
bytes, never into loss.

Everything here lives on host (numpy, float32): the cache's job is
residency beyond accelerator memory, so deltas must not pin device
buffers.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = ["CompressedDelta", "DenseLeaf", "LowRankLeaf",
           "apply_delta", "compress_delta"]


def _f32(x) -> np.ndarray:
    # host float32 view of a (possibly bf16, possibly device) leaf
    return np.asarray(jnp.asarray(x, jnp.float32))


@dataclasses.dataclass(frozen=True)
class LowRankLeaf:
    """``δ ≈ (u @ v).reshape(shape)``: rank-r factors of a matrix leaf
    (leading dims folded into rows, trailing dim = cols)."""
    u: np.ndarray                   # (rows, r) float32
    v: np.ndarray                   # (r, cols) float32
    shape: tuple[int, ...]

    @property
    def nbytes(self) -> int:
        return self.u.nbytes + self.v.nbytes

    def materialize(self) -> np.ndarray:
        return (self.u @ self.v).reshape(self.shape)


@dataclasses.dataclass(frozen=True)
class DenseLeaf:
    """Verbatim float32 delta — vectors, scalars, and matrix leaves whose
    rank-r truncation would exceed the fidelity tolerance."""
    x: np.ndarray

    @property
    def nbytes(self) -> int:
        return self.x.nbytes

    def materialize(self) -> np.ndarray:
        return self.x


@dataclasses.dataclass
class CompressedDelta:
    """One resident user's adaptation state: a pytree of
    :class:`LowRankLeaf` / :class:`DenseLeaf` mirroring the params tree."""
    leaves: PyTree
    dense_nbytes: int               # bytes of the uncompressed f32 delta

    @property
    def nbytes(self) -> int:
        return sum(leaf.nbytes for leaf in jax.tree.leaves(
            self.leaves, is_leaf=_is_delta_leaf))

    @property
    def compression(self) -> float:
        """dense_bytes / stored_bytes (≥ 1; higher is better)."""
        return self.dense_nbytes / max(self.nbytes, 1)


def _is_delta_leaf(x) -> bool:
    return isinstance(x, (LowRankLeaf, DenseLeaf))


def _compress_leaf(d: np.ndarray, rank: int, tol: float):
    if d.ndim < 2:
        return DenseLeaf(d)
    rows, cols = int(np.prod(d.shape[:-1])), d.shape[-1]
    r = min(rank, rows, cols)
    # factored storage must actually save bytes
    if r * (rows + cols) >= rows * cols:
        return DenseLeaf(d)
    m = d.reshape(rows, cols)
    u, s, vt = np.linalg.svd(m, full_matrices=False)
    total = float(np.sum(s * s))
    kept = float(np.sum(s[:r] * s[:r]))
    # relative Frobenius error of the truncation: sqrt(1 - kept/total)
    if total > 0.0 and 1.0 - kept / total > tol * tol:
        return DenseLeaf(d)
    return LowRankLeaf(np.ascontiguousarray(u[:, :r] * s[:r]),
                       np.ascontiguousarray(vt[:r]), d.shape)


def compress_delta(base: PyTree, adapted: PyTree, rank: int = 8,
                   tol: float = 0.3) -> CompressedDelta:
    """Compress ``adapted − base`` leaf-wise.

    ``rank`` bounds the factorization; ``tol`` is the per-leaf relative
    Frobenius error above which a leaf stays dense (fidelity gate).
    """
    deltas = jax.tree.map(lambda a, b: _f32(a) - _f32(b), adapted, base)
    dense_nbytes = sum(d.nbytes for d in jax.tree.leaves(deltas))
    leaves = jax.tree.map(lambda d: _compress_leaf(d, rank, tol), deltas)
    return CompressedDelta(leaves, dense_nbytes)


def apply_delta(base: PyTree, comp: CompressedDelta) -> PyTree:
    """Reconstruct adapted params: ``base + δ`` in float32, cast back to
    each base leaf's dtype.  This is the cache-hit path — one add per
    leaf, no gradient computation."""
    def leaf(b, d):
        out = jnp.asarray(b, jnp.float32) + jnp.asarray(d.materialize())
        return out.astype(b.dtype)

    return jax.tree.map(leaf, base, comp.leaves,
                        is_leaf=lambda x: _is_delta_leaf(x))
