"""From-scratch JAX optimizers (the paper's outer loop uses Adam and SGD)."""
from repro.optim.optimizers import (FusedSpec, Optimizer, sgd, momentum, adam,
                                    adamw, clip_by_global_norm,
                                    global_norm_scale, get_optimizer)

__all__ = ["FusedSpec", "Optimizer", "sgd", "momentum", "adam", "adamw",
           "clip_by_global_norm", "global_norm_scale", "get_optimizer"]
