"""From-scratch JAX optimizers (the paper's outer loop uses Adam and SGD)."""
from repro.optim.optimizers import Optimizer, sgd, momentum, adam, adamw, clip_by_global_norm, get_optimizer

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw",
           "clip_by_global_norm", "get_optimizer"]
