"""Minimal optimizer library (optax-style, written from scratch).

``Optimizer`` is a pair of pure functions:

    state   = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params  = tree_map(lambda p, u: p + u, params, updates)

All states are pytrees of arrays shaped like the parameters, so the whole
thing vmaps/pjits transparently — in particular, parameters with a leading
agent axis get per-agent optimizer moments for free (the paper's agents each
run a local Adam; only launch models are combined, moments stay local).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------

def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


class MomentumState(NamedTuple):
    velocity: PyTree


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return MomentumState(jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params):
        v = jax.tree.map(lambda v, g: beta * v + g, state.velocity, grads)
        return jax.tree.map(lambda v: -lr * v, v), MomentumState(v)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def u(m, v, p):
            upd = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                upd = upd - lr * weight_decay * p.astype(jnp.float32)
            return upd.astype(p.dtype)

        return jax.tree.map(u, mu, nu, params), AdamState(step, mu, nu)

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)


# ---------------------------------------------------------------------------
# Gradient transformations
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    table = {"sgd": sgd, "momentum": momentum, "adam": adam, "adamw": adamw}
    return table[name](lr, **kw)
