"""Minimal optimizer library (optax-style, written from scratch).

``Optimizer`` is a pair of pure functions:

    state   = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params  = tree_map(lambda p, u: p + u, params, updates)

All states are pytrees of arrays shaped like the parameters, so the whole
thing vmaps/pjits transparently — in particular, parameters with a leading
agent axis get per-agent optimizer moments for free (the paper's agents each
run a local Adam; only launch models are combined, moments stay local).

The per-leaf scalar math (moment recursions, update directions, the clip
scale) is factored into standalone functions so the tree-level ``update``
here and the fused combine-then-update kernel
(:mod:`repro.kernels.dif_combine`) evaluate the *same expressions* — the
kernel's :class:`FusedSpec` on each built-in optimizer names the recursion
and carries its hyperparameters.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FusedSpec:
    """Declarative form of an optimizer's per-leaf update: which scalar
    recursion (``kind``) with which hyperparameters.  The fused outer-update
    kernel (:func:`repro.core.fused.make_fused_outer`) consumes this to
    reproduce ``opt.update`` in-kernel; an optimizer without one (custom
    ``Optimizer`` instances) disqualifies the fused path."""

    kind: str                     # 'sgd' | 'momentum' | 'adam'
    lr: float
    b1: float = 0.9               # adam
    b2: float = 0.999             # adam
    eps: float = 1e-8             # adam
    weight_decay: float = 0.0     # adam(W): decoupled decay
    beta: float = 0.9             # momentum

    @property
    def n_moments(self) -> int:
        """fp32-moment buffers per parameter (adam: mu+nu; momentum: v)."""
        return {"sgd": 0, "momentum": 1, "adam": 2}[self.kind]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    fused: FusedSpec | None = None


# ---------------------------------------------------------------------------
# Shared per-leaf scalar math — the single source both the tree-level
# ``update`` functions below and the fused kernel evaluate
# ---------------------------------------------------------------------------

def adam_mu(mu, g32, b1: float):
    """First-moment (mean) recursion on an fp32 gradient leaf."""
    return b1 * mu + (1 - b1) * g32


def adam_nu(nu, g32, b2: float):
    """Second-moment (uncentered variance) recursion on an fp32 leaf."""
    return b2 * nu + (1 - b2) * jnp.square(g32)


def adam_direction(mu, nu, bc1, bc2, *, lr: float, eps: float,
                   weight_decay: float = 0.0, p32=None):
    """Bias-corrected Adam(W) update direction (fp32)."""
    u = -lr * (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
    if weight_decay:
        u = u - lr * weight_decay * p32
    return u


def momentum_velocity(v, g, beta: float):
    """Heavy-ball velocity recursion (in the velocity's own dtype)."""
    return beta * v + g


def momentum_direction(v, *, lr: float):
    return -lr * v


def sgd_direction(g, *, lr: float):
    return -lr * g


def global_norm_scale(grads: PyTree, max_norm: float) -> jax.Array:
    """The scalar :func:`clip_by_global_norm` multiplies every leaf by:
    ``min(1, max_norm / (‖g‖₂ + 1e-12))`` with the norm in fp32.
    ``max_norm=0.0`` is a valid total clip (scale 0)."""
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    return jnp.minimum(1.0, max_norm / (norm + 1e-12))


# ---------------------------------------------------------------------------
# SGD / momentum
# ---------------------------------------------------------------------------

def sgd(lr: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree.map(lambda g: sgd_direction(g, lr=lr), grads), state

    return Optimizer(init, update, fused=FusedSpec("sgd", lr))


class MomentumState(NamedTuple):
    velocity: PyTree


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return MomentumState(jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params):
        v = jax.tree.map(lambda v, g: momentum_velocity(v, g, beta),
                         state.velocity, grads)
        return (jax.tree.map(lambda v: momentum_direction(v, lr=lr), v),
                MomentumState(v))

    return Optimizer(init, update, fused=FusedSpec("momentum", lr, beta=beta))


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: adam_mu(m, g.astype(jnp.float32), b1),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: adam_nu(v, g.astype(jnp.float32), b2),
            state.nu, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def u(m, v, p):
            upd = adam_direction(m, v, bc1, bc2, lr=lr, eps=eps,
                                 weight_decay=weight_decay,
                                 p32=p.astype(jnp.float32))
            return upd.astype(p.dtype)

        return jax.tree.map(u, mu, nu, params), AdamState(step, mu, nu)

    return Optimizer(init, update,
                     fused=FusedSpec("adam", lr, b1=b1, b2=b2, eps=eps,
                                     weight_decay=weight_decay))


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay)


# ---------------------------------------------------------------------------
# Gradient transformations
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    scale = global_norm_scale(grads, max_norm)
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def get_optimizer(name: str, lr: float, **kw) -> Optimizer:
    table = {"sgd": sgd, "momentum": momentum, "adam": adam, "adamw": adamw}
    return table[name](lr, **kw)
