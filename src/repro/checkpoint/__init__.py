from repro.checkpoint.io import (save_checkpoint, restore_checkpoint,
                                 restore_centroid, latest_step)

__all__ = ["save_checkpoint", "restore_checkpoint", "restore_centroid",
           "latest_step"]
