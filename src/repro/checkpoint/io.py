"""Sharding-aware checkpointing to flat .npz archives.

Pytrees are flattened to ``path/to/leaf`` keys (jax.tree_util key paths).
On save, distributed arrays are gathered to host (fine at the scales we
materialize; the dry-run-only frontier configs are never materialized).
On restore, arrays are placed back with the provided sharding tree.
Writes are atomic (tmp file + rename) and versioned by step.
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np

PyTree = Any
_SEP = "::"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _fmt(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"x:{p}"


def _decode_raw(arr: np.ndarray) -> np.ndarray:
    """npz round-trips ml_dtypes arrays (bfloat16) as raw void bytes —
    reinterpret them so arithmetic and casts work after load."""
    if arr.dtype.kind == "V" and arr.dtype.itemsize == 2:
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    return arr


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    actual_tmp = tmp if os.path.exists(tmp) else tmp + ".npz"
    os.replace(actual_tmp, path)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _resolve_ckpt(ckpt_dir: str, step: int | None) -> str:
    """Path of the checkpoint to restore, with failure modes spelled out:
    a missing directory, a directory with no checkpoints, and an
    explicitly requested step that was never written each raise their own
    message (serve/resume callers surface these verbatim)."""
    if step is None:
        if not os.path.isdir(ckpt_dir):
            raise FileNotFoundError(
                f"checkpoint dir {ckpt_dir!r} does not exist")
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(
                f"checkpoint dir {ckpt_dir!r} exists but holds no "
                f"ckpt_*.npz files (contents: "
                f"{sorted(os.listdir(ckpt_dir))[:8]})")
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    if not os.path.exists(path):
        have = sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                      if (m := re.match(r"ckpt_(\d+)\.npz$", f))) \
            if os.path.isdir(ckpt_dir) else []
        raise FileNotFoundError(
            f"no checkpoint for step {step} in {ckpt_dir!r} "
            f"(available steps: {have})")
    return path


def _lookup(data, key: str, path: str) -> np.ndarray:
    if key not in data:
        have = sorted(data.files)
        raise KeyError(
            f"{path} has no leaf {key!r} — the checkpoint does not match "
            f"the requested spec (was it written by a different arch or "
            f"TrainState layout?).  Archive holds {len(have)} leaves, "
            f"e.g. {have[:4]}")
    return _decode_raw(data[key])


def restore_centroid(ckpt_dir: str, like_params: PyTree,
                     step: int | None = None) -> PyTree:
    """Restore the agent-**centroid** launch model from a TrainState
    checkpoint: every ``params`` leaf is loaded and averaged over its
    leading agent axis into the structure of single-agent ``like_params``
    (arrays or ShapeDtypeStructs).  This is the serve path's entry point —
    a checkpoint holds K per-agent models, serving wants the consensus one.
    """
    path = _resolve_ckpt(ckpt_dir, step)
    data = np.load(path)
    # the params field's key-path prefix inside TrainState, derived from a
    # probe so it tracks jax's key-path spelling
    from repro.core.meta_trainer import TrainState
    probe = jax.tree_util.tree_flatten_with_path(
        TrainState(np.zeros(()), {"probe": np.zeros(())}, ()))[0]
    prefix = next(_fmt(p[0][0]) for p in probe
                  if getattr(p[0][-1], "key", None) == "probe")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like_params)
    out = []
    for path_keys, leaf in paths:
        key = _SEP.join([prefix] + [_fmt(p) for p in path_keys])
        arr = _lookup(data, key, path)
        if arr.shape[1:] != tuple(leaf.shape):
            raise ValueError(
                f"agent-stacked shape mismatch for {key}: checkpoint "
                f"{arr.shape} vs (K,) + {tuple(leaf.shape)}")
        out.append(jax.numpy.asarray(
            arr.astype(np.float32).mean(axis=0)).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_checkpoint(ckpt_dir: str, like: PyTree, step: int | None = None,
                       shardings: PyTree | None = None) -> PyTree:
    """Restore into the structure of ``like`` (arrays or SDS).  If a
    shardings tree is given, leaves are device_put with it."""
    path = _resolve_ckpt(ckpt_dir, step)
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    out = []
    for (path_keys, leaf), shard in zip(paths, shard_leaves):
        key = _SEP.join(_fmt(p) for p in path_keys)
        arr = _lookup(data, key, path)
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
