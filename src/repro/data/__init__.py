"""Task-distribution substrates behind one `TaskSource` contract.

Every workload implements a single interface (repro.data.episodes):

  ``source.sample(step) -> Episode``
      One meta-iteration's data: ``support``/``query`` pytrees with
      canonical ``(K, tasks_per_agent, task_batch, ...)`` leading axes and a
      ``domains`` record of which domain each task was drawn from.  Pure
      function of ``(source config, seed, step)`` — bit-identical across
      hosts and across instances, so the prefetch pipeline may sample in
      any order.
  ``source.sources(K) -> [AgentStream, ...]``
      Per-agent streams.  Each stream carries its pairwise-disjoint
      ``domains`` shard (heterogeneous π_k, paper §4) assigned by
      ``partition_domains`` — the one sharding mechanism all sources share.
  ``source.eval_sample(n_tasks, split=...) -> Episode``
      Task-leading (no agent axis) episodes for adaptation eval.  The
      ``split`` argument is the recurring-vs-unseen generalization contract
      (Fallah et al. 2021), spelled identically on every source:
        ``split='recurring'``  tasks from the *trained* domain universe
                               (the union of all agent shards);
        ``split='unseen'``     tasks from domains held out of every shard
                               (sine: the held-out amplitude bands via
                               ``holdout_domains``; few-shot: the meta-test
                               classes; LM: ``holdout_domains``) — always
                               disjoint from 'recurring';
        ``split=None``         each source's legacy default universe
                               (sine: full range, few-shot: meta-test, LM:
                               held-out when configured, else full).
      ``repro.eval.EvalHarness`` consumes this surface to report
      per-inner-step adaptation curves and the generalization gap for any
      ``TrainState`` — during training (``launch/train.py --eval-every``),
      post-hoc (benchmarks), and at serve time (``launch/serve.py``).
  metadata: ``K``, ``tasks_per_agent``, ``n_domains``, ``heterogeneity``.

Three conforming sources ship in this package — ``SineTaskSource``
(amplitude bands), ``FewShotTaskSource`` (class shards), ``LMTaskSource``
(Markov domain shards, vectorized generation) — plus
``MetaBatchPipeline``, the background-thread prefetcher that samples and
``device_put``s episode i+1 while the device runs step i.  A new workload
is one new ``TaskSource``; the trainer, examples, and benchmarks need no
changes.

The pre-`TaskSource` module-level APIs (``SineTaskDistribution``,
``FewShotSampler``, ``LMTaskSampler``) remain as thin building blocks the
sources wrap.
"""
from repro.data.episodes import (AgentStream, DomainShardedSource, Episode,
                                 TaskSource, partition_domains)
from repro.data.pipeline import MetaBatchPipeline
from repro.data.sine import (SineTaskDistribution, SineTaskSource,
                             agent_sine_distributions)
from repro.data.fewshot import FewShotSampler, FewShotTaskSource
from repro.data.lm_tasks import LMTaskSampler, LMTaskSource

__all__ = ["Episode", "TaskSource", "AgentStream", "DomainShardedSource",
           "partition_domains", "MetaBatchPipeline",
           "SineTaskDistribution", "SineTaskSource",
           "agent_sine_distributions",
           "FewShotSampler", "FewShotTaskSource",
           "LMTaskSampler", "LMTaskSource"]
