from repro.data.sine import SineTaskDistribution, agent_sine_distributions
from repro.data.fewshot import FewShotSampler
from repro.data.lm_tasks import LMTaskSampler

__all__ = ["SineTaskDistribution", "agent_sine_distributions",
           "FewShotSampler", "LMTaskSampler"]
