"""Unified episode/task-stream substrate for every Dif-MAML workload.

Dif-MAML's premise (paper §4) is that tasks live on *agents* with
heterogeneous per-agent distributions π_k.  This module is the single place
that premise is encoded: an :class:`Episode` is one meta-iteration's data
with canonical ``(K, T, tb, ...)`` leading axes, a :class:`TaskSource` is
anything that can produce them, and :func:`partition_domains` is the one
mechanism that assigns each agent a pairwise-disjoint shard of the domain
universe — sine amplitude bands, few-shot class shards, and LM Markov
domains are three instances of it, not three bespoke loops.

Determinism contract: ``sample(step)`` is a pure function of
``(source config, seed, step)`` — two instances with the same fields
produce bit-identical episodes on any host, in any order (the prefetch
pipeline relies on the order-independence).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import numpy as np

PyTree = Any

__all__ = ["Episode", "TaskSource", "AgentStream", "DomainShardedSource",
           "partition_domains", "EVAL_SPLITS"]

# The recurring-vs-unseen eval contract (Fallah et al. 2021): 'recurring'
# draws eval tasks from the domains the agents trained on, 'unseen' from the
# held-out tail nobody's shard contains.  The generalization gap between the
# two is the metric the EvalHarness reports.
EVAL_SPLITS = ("recurring", "unseen")

# Distinct salts keep the train / eval rng streams of one seed disjoint.
_TRAIN_SALT = 0x5EED_0001
_EVAL_SALT = 0x5EED_0002


def episode_rng(salt: int, seed: int, step: int, agent: int = 0
                ) -> np.random.Generator:
    """Deterministic per-(seed, step, agent) generator (cross-host stable)."""
    return np.random.default_rng([salt, seed, step, agent])


def partition_domains(n_domains: int, K: int) -> list[np.ndarray]:
    """Split ``range(n_domains)`` into K contiguous pairwise-disjoint shards
    covering every domain (sizes differ by at most one).  This is the π_k
    heterogeneity mechanism shared by all task sources."""
    if K < 1:
        raise ValueError(f"need at least one agent, got K={K}")
    if n_domains < K:
        raise ValueError(
            f"cannot shard {n_domains} domains across K={K} agents: every "
            f"agent needs a non-empty disjoint shard (need n_domains >= K)")
    return list(np.array_split(np.arange(n_domains), K))


@dataclasses.dataclass
class Episode:
    """One meta-iteration's data.

    ``support``/``query`` are pytrees whose leaves share the canonical
    leading axes ``(K, tasks_per_agent, task_batch, ...)`` — or, for eval
    episodes (:meth:`TaskSource.eval_sample`), ``(n_tasks, ...)`` with no
    agent axis.  ``domains`` records which domain(s) each task was drawn
    from, shape ``(K, T)`` (or ``(K, T, way)`` for class-composed tasks);
    it exists so heterogeneity is *testable*, not inferred.
    """
    support: PyTree
    query: PyTree
    domains: np.ndarray | None = None
    step: int | None = None

    def to_device(self) -> tuple[PyTree, PyTree]:
        """``(support, query)`` transferred to the default device — the
        standard ``prepare`` for pipelines feeding a host-mesh meta step
        (``MetaBatchPipeline(src, prepare=Episode.to_device)``)."""
        import jax
        return jax.device_put((self.support, self.query))

    def as_flat_batch(self) -> PyTree:
        """Inverse of ``launch.steps.split_meta_batch``: concatenate support
        and query along the task-batch axis and flatten ``(K, T, 2·tb)`` to
        the global batch axis ``B = K·T·2·tb`` the jitted train step takes.
        """
        import jax

        def leaf(s, q):
            both = np.concatenate([np.asarray(s), np.asarray(q)], axis=2)
            return both.reshape((-1,) + both.shape[3:])

        return jax.tree.map(leaf, self.support, self.query)


@runtime_checkable
class TaskSource(Protocol):
    """The contract every workload implements exactly once.

    Metadata:
      ``K``               number of agents the source is bound to
      ``tasks_per_agent`` T, tasks per agent per meta-iteration
      ``n_domains``       size of the discrete domain universe
      ``heterogeneity``   short label of the π_k mechanism
                          (e.g. 'amplitude-bands', 'class-shards')

    Methods:
      ``sources(K=None)``       per-agent streams (disjoint domain shards)
      ``sample(step)``          -> Episode with (K, T, tb, ...) leading axes
      ``eval_sample(n_tasks, split=...)``
                                -> Episode with (n_tasks, ...) leading axes.
                                   ``split='recurring'`` draws from the
                                   trained domain shards, ``split='unseen'``
                                   from held-out domains (disjoint from every
                                   agent's shard); ``split=None`` keeps each
                                   source's legacy default universe.
    """
    K: int
    tasks_per_agent: int
    heterogeneity: str

    @property
    def n_domains(self) -> int: ...

    def sources(self, K: int | None = None) -> list["AgentStream"]: ...

    def sample(self, step: int) -> Episode: ...

    def eval_sample(self, n_tasks: int, seed: int | None = None,
                    split: str | None = None) -> Episode: ...


@dataclasses.dataclass
class AgentStream:
    """Agent k's view of a :class:`TaskSource`: its disjoint domain shard
    plus a per-agent episode stream (exactly the agent-k slice of the
    source's stacked episode, so stream and stacked paths can never drift).
    """
    source: "DomainShardedSource"
    agent: int
    domains: np.ndarray

    def sample(self, step: int) -> Episode:
        import jax
        ep = self.source.sample(step)
        k = self.agent
        take = lambda x: x[k]
        return Episode(jax.tree.map(take, ep.support),
                       jax.tree.map(take, ep.query),
                       domains=None if ep.domains is None else ep.domains[k],
                       step=step)


class DomainShardedSource:
    """Shared mechanics for domain-sharded task sources.

    Subclasses provide ``K``, ``tasks_per_agent``, ``seed``, ``n_domains``
    (optionally ``n_train_domains`` when some domains are held out for
    eval) and either implement ``_agent_episode`` — one agent's
    ``(support, query, domains)`` for one step — or override ``sample``
    wholesale (the LM source does, to batch all agents into one vectorized
    generator pass).
    """

    # --- sharding ----------------------------------------------------------

    @property
    def n_train_domains(self) -> int:
        return self.n_domains

    def shards(self) -> list[np.ndarray]:
        return partition_domains(self.n_train_domains, self.K)

    def eval_domain_pool(self, split: str | None) -> np.ndarray:
        """Domain ids an eval episode of ``split`` may draw from.

        'recurring' = the trained shards' union, 'unseen' = the held-out
        tail (requires some domains held out), None/'full' = the whole
        universe.  Sources whose unseen split is not a tail of the same
        universe (e.g. few-shot meta-test classes) override this.
        """
        if split in (None, "full"):
            return np.arange(self.n_domains)
        if split == "recurring":
            return np.arange(self.n_train_domains)
        if split == "unseen":
            if self.n_train_domains >= self.n_domains:
                raise ValueError(
                    f"{type(self).__name__} has no held-out domains for "
                    f"split='unseen' (n_domains={self.n_domains}, all "
                    f"trained); configure holdout_domains > 0")
            return np.arange(self.n_train_domains, self.n_domains)
        raise ValueError(
            f"unknown eval split {split!r}: expected one of "
            f"{EVAL_SPLITS + ('full', None)}")

    def sources(self, K: int | None = None) -> list[AgentStream]:
        if K is not None and K != self.K:
            raise ValueError(
                f"source is bound to K={self.K} agents; rebuild it to "
                f"stream for K={K}")
        return [AgentStream(self, k, shard)
                for k, shard in enumerate(self.shards())]

    # --- rng ---------------------------------------------------------------

    def _rng(self, step: int, agent: int = 0) -> np.random.Generator:
        return episode_rng(_TRAIN_SALT, self.seed, step, agent)

    def _eval_rng(self, seed: int | None) -> np.random.Generator:
        return episode_rng(_EVAL_SALT, self.seed if seed is None else seed, 0)

    # --- episode assembly --------------------------------------------------

    def _agent_episode(self, k: int, domains: np.ndarray,
                       rng: np.random.Generator
                       ) -> tuple[PyTree, PyTree, np.ndarray]:
        raise NotImplementedError

    def sample(self, step: int) -> Episode:
        import jax
        parts = [self._agent_episode(k, shard, self._rng(step, k))
                 for k, shard in enumerate(self.shards())]
        sups, qrys, doms = zip(*parts)
        stack = lambda *xs: np.stack(xs, axis=0)
        return Episode(jax.tree.map(stack, *sups), jax.tree.map(stack, *qrys),
                       domains=np.stack(doms, axis=0), step=step)
