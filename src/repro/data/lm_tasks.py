"""Synthetic per-domain language-model meta-tasks.

The production analogue of the paper's heterogeneous agents: each agent
holds a distribution over *domains* (a "task" = a domain); a domain is a
seeded synthetic Markov source over the vocabulary.  Adapting the launch
model to a new domain with a few gradient steps is exactly the MAML setting,
at LM scale.

Sequences are generated with a light-weight order-1 Markov chain whose
transition structure is domain-seeded (deterministic given ``domain_id``),
so data is reproducible across hosts without files.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.episodes import DomainShardedSource, Episode


@dataclasses.dataclass
class LMTaskSampler:
    vocab_size: int
    seq_len: int
    n_domains: int = 64
    branching: int = 32     # out-degree of the Markov chain per state bucket
    n_buckets: int = 256    # states are token % n_buckets
    seed: int = 0

    def _domain_table(self, domain_id: int) -> np.ndarray:
        """(n_buckets, branching) allowed next-tokens for this domain."""
        rng = np.random.default_rng(self.seed * 100003 + int(domain_id))
        return rng.integers(0, self.vocab_size,
                            size=(self.n_buckets, self.branching))

    def sample_tokens(self, domain_id: int, batch: int, rng: np.random.Generator
                      ) -> np.ndarray:
        table = self._domain_table(domain_id)
        toks = np.empty((batch, self.seq_len + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch)
        for t in range(self.seq_len):
            bucket = toks[:, t] % self.n_buckets
            choice = rng.integers(0, self.branching, size=batch)
            toks[:, t + 1] = table[bucket, choice]
        return toks

    def sample_task(self, domain_id: int, batch: int, seed: int = 0):
        """Returns {tokens, labels} of shape (batch, seq_len)."""
        rng = np.random.default_rng(seed)
        toks = self.sample_tokens(domain_id, batch, rng)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def sample_agents(self, K: int, tasks_per_agent: int, task_batch: int,
                      step: int = 0):
        """Dif-MAML step data: support/query dicts with leading
        (K, tasks_per_agent, task_batch, seq).  Agent k draws domains from
        its own shard of the domain universe (heterogeneous π_k).

        Legacy per-task python-loop path, kept as the reference the
        ``pipeline_lm_vectorized`` benchmark row measures against;
        production code uses :class:`LMTaskSource`, which batches all
        K·T·tb sequences into one generator pass."""
        per_agent = max(1, self.n_domains // K)
        sup_t, sup_l, qry_t, qry_l = [], [], [], []
        rng = np.random.default_rng(self.seed + 7919 * step)
        for k in range(K):
            st, sl, qt, ql = [], [], [], []
            for t in range(tasks_per_agent):
                dom = k * per_agent + int(rng.integers(0, per_agent))
                s = self.sample_task(dom, task_batch, seed=int(rng.integers(2**31)))
                q = self.sample_task(dom, task_batch, seed=int(rng.integers(2**31)))
                st.append(s["tokens"]); sl.append(s["labels"])
                qt.append(q["tokens"]); ql.append(q["labels"])
            sup_t.append(np.stack(st)); sup_l.append(np.stack(sl))
            qry_t.append(np.stack(qt)); qry_l.append(np.stack(ql))
        pack = lambda a: np.stack(a, axis=0)
        support = {"tokens": pack(sup_t), "labels": pack(sup_l)}
        query = {"tokens": pack(qry_t), "labels": pack(qry_l)}
        return support, query


@dataclasses.dataclass
class LMTaskSource(DomainShardedSource):
    """`TaskSource` view of the LM meta-task universe: a domain = one seeded
    Markov source, ``partition_domains`` gives each agent a disjoint domain
    shard (heterogeneous π_k), and ``holdout_domains`` reserves the tail of
    the universe for :meth:`eval_sample` — the recurring-vs-unseen task
    split of Fallah et al. 2021.

    Episode generation is vectorized: all K·T·2·tb sequences of a step run
    through ONE Markov-generator pass (domain transition tables stacked and
    indexed per row, all randomness pre-drawn per agent) instead of the
    K×T python loop of ``LMTaskSampler.sample_agents`` — same O(seq) chain
    recurrence, but each iteration advances every row at once and each
    domain table is built (and cached) once instead of per task.
    """
    vocab_size: int = 1024
    seq_len: int = 64
    K: int = 4
    tasks_per_agent: int = 2
    task_batch: int = 2
    n_domains: int = 64
    branching: int = 32
    n_buckets: int = 256
    holdout_domains: int = 0
    seed: int = 0
    heterogeneity: str = "domain-shards"

    def __post_init__(self):
        self.sampler = LMTaskSampler(
            vocab_size=self.vocab_size, seq_len=self.seq_len,
            n_domains=self.n_domains, branching=self.branching,
            n_buckets=self.n_buckets, seed=self.seed)
        self._stacked: np.ndarray | None = None

    @property
    def n_train_domains(self) -> int:
        return self.n_domains - self.holdout_domains

    def _tables(self) -> np.ndarray:
        """(n_domains, n_buckets, branching) stacked transition tables,
        built once and indexed by domain id per row thereafter (stacking
        per step would memcpy every table on every sample)."""
        if self._stacked is None:
            self._stacked = np.stack(
                [self.sampler._domain_table(d) for d in range(self.n_domains)]
            ).astype(np.int32)
        return self._stacked

    def _generate(self, row_dom: np.ndarray, first: np.ndarray,
                  choice: np.ndarray) -> np.ndarray:
        """One batched Markov pass: rows (R,) domains, (R,) first tokens,
        (R, seq) branch choices -> (R, seq+1) token sequences."""
        tables = self._tables()
        toks = np.empty((len(row_dom), self.seq_len + 1), dtype=np.int64)
        toks[:, 0] = first
        for t in range(self.seq_len):
            toks[:, t + 1] = tables[row_dom, toks[:, t] % self.n_buckets,
                                    choice[:, t]]
        return toks

    @staticmethod
    def _pack(toks: np.ndarray) -> dict:
        return {"tokens": toks[..., :-1].astype(np.int32),
                "labels": toks[..., 1:].astype(np.int32)}

    def sample(self, step: int) -> Episode:
        K, T, tb, S = self.K, self.tasks_per_agent, self.task_batch, self.seq_len
        rows_per_agent = T * 2 * tb          # support + query
        doms, firsts, choices = [], [], []
        for k, shard in enumerate(self.shards()):
            rng = self._rng(step, k)
            doms.append(rng.choice(shard, size=T))
            firsts.append(rng.integers(0, self.vocab_size,
                                       size=rows_per_agent))
            choices.append(rng.integers(0, self.branching,
                                        size=(rows_per_agent, S)))
        doms = np.stack(doms)                                    # (K, T)
        row_dom = np.repeat(doms.reshape(-1), 2 * tb)            # (K·T·2tb,)
        toks = self._generate(row_dom, np.concatenate(firsts),
                              np.concatenate(choices))
        folded = toks.reshape(K, T, 2 * tb, S + 1)
        return Episode(self._pack(folded[:, :, :tb]),
                       self._pack(folded[:, :, tb:]),
                       domains=doms, step=step)

    def eval_sample(self, n_tasks: int, seed: int | None = None,
                    split: str | None = None,
                    task_batch: int | None = None) -> Episode:
        """Eval tasks: ``split=None`` keeps the legacy default — held-out
        domains when ``holdout_domains > 0`` (the unseen-task split),
        otherwise the full universe; 'recurring'/'unseen' select the
        trained shards / held-out tail explicitly."""
        tb = self.task_batch if task_batch is None else task_batch
        rng = self._eval_rng(seed)
        if split is None:
            split = "unseen" if self.holdout_domains else "full"
        dom = rng.choice(self.eval_domain_pool(split), size=n_tasks)
        rows = n_tasks * 2 * tb
        toks = self._generate(np.repeat(dom, 2 * tb),
                              rng.integers(0, self.vocab_size, size=rows),
                              rng.integers(0, self.branching,
                                           size=(rows, self.seq_len)))
        folded = toks.reshape(n_tasks, 2 * tb, self.seq_len + 1)
        return Episode(self._pack(folded[:, :tb]), self._pack(folded[:, tb:]),
                       domains=dom)
