"""Synthetic per-domain language-model meta-tasks.

The production analogue of the paper's heterogeneous agents: each agent
holds a distribution over *domains* (a "task" = a domain); a domain is a
seeded synthetic Markov source over the vocabulary.  Adapting the launch
model to a new domain with a few gradient steps is exactly the MAML setting,
at LM scale.

Sequences are generated with a light-weight order-1 Markov chain whose
transition structure is domain-seeded (deterministic given ``domain_id``),
so data is reproducible across hosts without files.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LMTaskSampler:
    vocab_size: int
    seq_len: int
    n_domains: int = 64
    branching: int = 32     # out-degree of the Markov chain per state bucket
    n_buckets: int = 256    # states are token % n_buckets
    seed: int = 0

    def _domain_table(self, domain_id: int) -> np.ndarray:
        """(n_buckets, branching) allowed next-tokens for this domain."""
        rng = np.random.default_rng(self.seed * 100003 + int(domain_id))
        return rng.integers(0, self.vocab_size,
                            size=(self.n_buckets, self.branching))

    def sample_tokens(self, domain_id: int, batch: int, rng: np.random.Generator
                      ) -> np.ndarray:
        table = self._domain_table(domain_id)
        toks = np.empty((batch, self.seq_len + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch)
        for t in range(self.seq_len):
            bucket = toks[:, t] % self.n_buckets
            choice = rng.integers(0, self.branching, size=batch)
            toks[:, t + 1] = table[bucket, choice]
        return toks

    def sample_task(self, domain_id: int, batch: int, seed: int = 0):
        """Returns {tokens, labels} of shape (batch, seq_len)."""
        rng = np.random.default_rng(seed)
        toks = self.sample_tokens(domain_id, batch, rng)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def sample_agents(self, K: int, tasks_per_agent: int, task_batch: int,
                      step: int = 0):
        """Dif-MAML step data: support/query dicts with leading
        (K, tasks_per_agent, task_batch, seq).  Agent k draws domains from
        its own shard of the domain universe (heterogeneous π_k)."""
        per_agent = max(1, self.n_domains // K)
        sup_t, sup_l, qry_t, qry_l = [], [], [], []
        rng = np.random.default_rng(self.seed + 7919 * step)
        for k in range(K):
            st, sl, qt, ql = [], [], [], []
            for t in range(tasks_per_agent):
                dom = k * per_agent + int(rng.integers(0, per_agent))
                s = self.sample_task(dom, task_batch, seed=int(rng.integers(2**31)))
                q = self.sample_task(dom, task_batch, seed=int(rng.integers(2**31)))
                st.append(s["tokens"]); sl.append(s["labels"])
                qt.append(q["tokens"]); ql.append(q["labels"])
            sup_t.append(np.stack(st)); sup_l.append(np.stack(sl))
            qry_t.append(np.stack(qt)); qry_l.append(np.stack(ql))
        pack = lambda a: np.stack(a, axis=0)
        support = {"tokens": pack(sup_t), "labels": pack(sup_l)}
        query = {"tokens": pack(qry_t), "labels": pack(qry_l)}
        return support, query
