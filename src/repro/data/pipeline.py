"""Async sharded meta-batch pipeline.

Episode generation is host-side python/numpy (Markov chains, prototype
mixing) and used to run *between* jitted steps — the device sat idle while
the host sampled, and the host sat idle while the device stepped.
:class:`MetaBatchPipeline` moves sampling (and the ``device_put`` onto the
train step's ``NamedSharding``s, via ``prepare``) onto a background thread
so episode ``i+1`` is generated and transferred while the device runs step
``i``.  The jitted step releases the GIL inside XLA, so the overlap is real
even on a single host.

``depth=0`` is the synchronous fallback (no thread, sample-on-demand) used
by tests and debugging; any depth produces the identical batch sequence
because ``TaskSource.sample(step)`` is a pure function of ``step``.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable

from repro.data.episodes import Episode, TaskSource

__all__ = ["MetaBatchPipeline"]

_POLL_S = 0.05


class MetaBatchPipeline:
    """Iterator of device-ready meta-batches drawn from a :class:`TaskSource`.

    Args:
      source:     any TaskSource; ``source.sample(step)`` is called for
                  ``step = start_step, start_step+1, ...``.
      depth:      prefetch buffer depth; 0 = synchronous (no thread).
      prepare:    ``Episode -> batch`` transform run on the producer side
                  (flattening, ``jax.device_put`` with shardings, ...).
                  Default: the Episode itself.  With ``stack > 1`` it
                  receives a *list* of ``stack`` consecutive Episodes.
      start_step: first step index (e.g. a restored checkpoint's step).
      stack:      meta-batches per item: each ``next()`` yields ``stack``
                  consecutive steps' episodes (as one ``prepare``d item) —
                  the superstep driver's per-dispatch input.  The sample
                  sequence is identical to ``stack=1``; only the grouping
                  changes.
    """

    def __init__(self, source: TaskSource, *, depth: int = 2,
                 prepare: Callable[[Episode], Any] | None = None,
                 start_step: int = 0, stack: int = 1):
        if stack < 1:
            raise ValueError(f"stack must be >= 1, got {stack}")
        self.source = source
        self.depth = depth
        self.stack = stack
        self._prepare = prepare if prepare is not None else (lambda ep: ep)
        self._step = start_step
        self._exc: BaseException | None = None
        self._thread = None
        if depth > 0:
            self._queue: queue.Queue = queue.Queue(maxsize=depth)
            self._stop = threading.Event()
            self._thread = threading.Thread(
                target=self._worker, name="meta-batch-prefetch", daemon=True)
            self._thread.start()

    # --- producer ----------------------------------------------------------

    def _sample_item(self, step: int) -> Any:
        """One prepared item: a single episode, or ``stack`` consecutive
        episodes handed to ``prepare`` as a list."""
        if self.stack == 1:
            return self._prepare(self.source.sample(step))
        return self._prepare([self.source.sample(step + j)
                              for j in range(self.stack)])

    def _worker(self) -> None:
        step = self._step
        try:
            while not self._stop.is_set():
                item = self._sample_item(step)
                step += self.stack
                while not self._stop.is_set():
                    try:
                        self._queue.put(item, timeout=_POLL_S)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surfaced to the consumer in __next__
            self._exc = e
            self._stop.set()

    # --- consumer ----------------------------------------------------------

    def __iter__(self) -> "MetaBatchPipeline":
        return self

    def __next__(self) -> Any:
        if self.depth <= 0:
            item = self._sample_item(self._step)
            self._step += self.stack
            return item
        while True:
            try:
                item = self._queue.get(timeout=_POLL_S)
            except queue.Empty:
                if self._exc is not None:
                    raise RuntimeError(
                        "MetaBatchPipeline prefetch worker failed"
                    ) from self._exc
                if self._thread is None or not self._thread.is_alive():
                    raise StopIteration   # stop() was called / worker gone
                continue
            self._step += self.stack
            return item

    @property
    def step(self) -> int:
        """Index of the next batch the consumer will receive."""
        return self._step

    # --- lifecycle ---------------------------------------------------------

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        while True:  # drain so a blocked put() observes the stop event
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)
        self._thread = None
        while True:  # a blocked put() may have landed one last item
            try:
                self._queue.get_nowait()
            except queue.Empty:
                break

    def __enter__(self) -> "MetaBatchPipeline":
        return self

    def __exit__(self, *exc_info) -> None:
        self.stop()
