"""Synthetic N-way K-shot episodic sampler (Omniglot-like; paper §4.2).

The real Omniglot/MiniImagenet archives are not available offline, so we
construct a *structured* synthetic surrogate with the same episodic
statistics: a universe of ``n_classes`` class prototypes in pixel space;
samples = prototype + per-sample deformation (random affine-ish mixing +
noise).  Classes are meta-split into train/test so meta-generalization is
measurable, and the paper's comparison (centralized vs Dif vs non-coop) is
reproduced on identical semantics: the cooperative strategies see more
tasks/data per iteration than a single agent.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.episodes import DomainShardedSource, Episode


@dataclasses.dataclass
class FewShotSampler:
    n_classes: int = 200
    image_hw: int = 14
    n_way: int = 5
    k_shot: int = 1
    n_query: int = 5
    noise: float = 0.15
    seed: int = 0
    train_fraction: float = 0.8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        d = self.image_hw * self.image_hw
        # class prototypes: smooth random images (low-frequency mixtures)
        freqs = rng.normal(size=(self.n_classes, 8, d)).astype(np.float32)
        coefs = rng.normal(size=(self.n_classes, 8, 1)).astype(np.float32)
        self._protos = np.tanh((freqs * coefs).sum(axis=1))  # (C, d)
        n_train = int(self.n_classes * self.train_fraction)
        self._train_classes = np.arange(n_train)
        self._test_classes = np.arange(n_train, self.n_classes)
        self._rng = rng

    @property
    def dim(self) -> int:
        return self.image_hw * self.image_hw

    def _episode(self, classes: np.ndarray, rng: np.random.Generator):
        way = rng.choice(classes, size=self.n_way, replace=False)
        return self.episode_from_classes(way, rng)

    def episode_from_classes(self, way: np.ndarray, rng: np.random.Generator):
        """Support/query for one episode over an explicit class selection."""
        n = self.k_shot + self.n_query
        protos = self._protos[way]  # (way, d)
        x = protos[:, None, :] + self.noise * rng.normal(
            size=(self.n_way, n, self.dim)).astype(np.float32)
        y = np.broadcast_to(np.arange(self.n_way)[:, None], (self.n_way, n))
        # shuffle within support/query
        xs = x[:, : self.k_shot].reshape(-1, self.dim)
        ys = y[:, : self.k_shot].reshape(-1)
        xq = x[:, self.k_shot:].reshape(-1, self.dim)
        yq = y[:, self.k_shot:].reshape(-1)
        return (xs.astype(np.float32), ys.astype(np.int32)), \
               (xq.astype(np.float32), yq.astype(np.int32))

    def sample(self, n_tasks: int, split: str = "train", seed: int | None = None):
        """Returns support (x,y) and query (x,y) stacked over tasks."""
        rng = self._rng if seed is None else np.random.default_rng(seed)
        classes = self._train_classes if split == "train" else self._test_classes
        sup, qry = zip(*[self._episode(classes, rng) for _ in range(n_tasks)])
        sx = np.stack([s[0] for s in sup]); sy = np.stack([s[1] for s in sup])
        qx = np.stack([q[0] for q in qry]); qy = np.stack([q[1] for q in qry])
        return (sx, sy), (qx, qy)

    def sample_agents(self, K: int, tasks_per_agent: int, split: str = "train"):
        """Leading (K, T, ...) axes, all agents sharing the class universe
        (the paper's classification setting: same tasks, limited per-agent
        data).  Legacy path — the heterogeneous-by-default view is
        :class:`FewShotTaskSource`."""
        sup, qry = self.sample(K * tasks_per_agent, split)
        reshape = lambda a: a.reshape((K, tasks_per_agent) + a.shape[1:])
        return ((reshape(sup[0]), reshape(sup[1])),
                (reshape(qry[0]), reshape(qry[1])))


@dataclasses.dataclass
class FewShotTaskSource(DomainShardedSource):
    """`TaskSource` view of the few-shot benchmark: a domain = one meta-train
    class, and ``partition_domains`` gives each agent a disjoint class shard
    — agent k composes its N-way episodes only from its own classes
    (heterogeneous π_k), while :meth:`eval_sample` draws from the meta-test
    classes shared by nobody (meta-generalization stays measurable).
    """
    K: int = 6
    tasks_per_agent: int = 2
    n_classes: int = 200
    image_hw: int = 14
    n_way: int = 5
    k_shot: int = 1
    n_query: int = 5
    noise: float = 0.15
    train_fraction: float = 0.8
    seed: int = 0
    heterogeneity: str = "class-shards"

    def __post_init__(self):
        self.sampler = FewShotSampler(
            n_classes=self.n_classes, image_hw=self.image_hw,
            n_way=self.n_way, k_shot=self.k_shot, n_query=self.n_query,
            noise=self.noise, seed=self.seed,
            train_fraction=self.train_fraction)
        per_agent = len(self.sampler._train_classes) // self.K
        if per_agent < self.n_way:
            raise ValueError(
                f"K={self.K} agents over "
                f"{len(self.sampler._train_classes)} meta-train classes "
                f"leaves shards of ~{per_agent} classes — too few for "
                f"{self.n_way}-way episodes (need n_classes*train_fraction "
                f">= K*n_way = {self.K * self.n_way})")

    @property
    def dim(self) -> int:
        return self.image_hw * self.image_hw

    @property
    def n_domains(self) -> int:
        return len(self.sampler._train_classes)

    @property
    def n_test_domains(self) -> int:
        return len(self.sampler._test_classes)

    def eval_domain_pool(self, split):
        """'recurring' = meta-train classes (the trained shards' union),
        'unseen' = meta-test classes (shared by no agent), 'full' = both.
        The default eval split is 'unseen' — the classic meta-test."""
        if split == "recurring":
            return self.sampler._train_classes
        if split in (None, "unseen"):
            return self.sampler._test_classes
        if split == "full":
            return np.arange(self.n_classes)
        raise ValueError(f"unknown eval split {split!r}")

    def _agent_episode(self, k, domains, rng):
        ways, sup, qry = [], [], []
        for _ in range(self.tasks_per_agent):
            way = rng.choice(domains, size=self.n_way, replace=False)
            s, q = self.sampler.episode_from_classes(way, rng)
            ways.append(way); sup.append(s); qry.append(q)
        stack = lambda *xs: np.stack(xs, axis=0)
        import jax
        return (jax.tree.map(stack, *sup), jax.tree.map(stack, *qry),
                np.stack(ways, axis=0))

    def eval_sample(self, n_tasks: int, seed: int | None = None,
                    split: str | None = None) -> Episode:
        rng = self._eval_rng(seed)
        pool = self.eval_domain_pool(split)
        ways, sup, qry = [], [], []
        for _ in range(n_tasks):
            way = rng.choice(pool, size=self.n_way, replace=False)
            s, q = self.sampler.episode_from_classes(way, rng)
            ways.append(way); sup.append(s); qry.append(q)
        stack = lambda *xs: np.stack(xs, axis=0)
        import jax
        return Episode(jax.tree.map(stack, *sup), jax.tree.map(stack, *qry),
                       domains=np.stack(ways, axis=0))
