"""The paper's sine-wave regression benchmark (§4.1, after Finn et al. 2017).

Each task: predict ``y = amplitude * sin(x + phase)`` from ``x ∈ [-5, 5]``.
Phases ~ U[0, π].  The amplitude interval [0.1, 5.0] is evenly partitioned
into K sub-intervals, one per agent — agents see *different* task
distributions (the paper's heterogeneous setting).  Evaluation tasks draw
from the full interval.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.episodes import DomainShardedSource, Episode

AMP_LO, AMP_HI = 0.1, 5.0
PHASE_LO, PHASE_HI = 0.0, np.pi
X_LO, X_HI = -5.0, 5.0


@dataclasses.dataclass
class SineTaskDistribution:
    amp_lo: float = AMP_LO
    amp_hi: float = AMP_HI
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def sample_batch(self, n_tasks: int, shots: int):
        """Returns (support, query): each (x, y) with shape
        (n_tasks, shots, 1).  Support/query are disjoint draws from the same
        sinusoid (the paper's two-batch X_in / X_o scheme, footnote 1)."""
        amp = self._rng.uniform(self.amp_lo, self.amp_hi, size=(n_tasks, 1, 1))
        phase = self._rng.uniform(PHASE_LO, PHASE_HI, size=(n_tasks, 1, 1))
        xs = self._rng.uniform(X_LO, X_HI, size=(n_tasks, 2 * shots, 1))
        ys = amp * np.sin(xs + phase)
        xs = xs.astype(np.float32)
        ys = ys.astype(np.float32)
        return ((xs[:, :shots], ys[:, :shots]),
                (xs[:, shots:], ys[:, shots:]))


def agent_sine_distributions(K: int, seed: int = 0) -> list[SineTaskDistribution]:
    """Partition [0.1, 5.0] into K equal amplitude intervals (paper §4.1)."""
    edges = np.linspace(AMP_LO, AMP_HI, K + 1)
    return [SineTaskDistribution(float(edges[k]), float(edges[k + 1]), seed + k)
            for k in range(K)]


def stacked_agent_batch(dists, tasks_per_agent: int, shots: int):
    """Sample one Dif-MAML step's data: pytrees with leading
    (K, tasks_per_agent, shots, 1) axes."""
    sup_x, sup_y, qry_x, qry_y = [], [], [], []
    for d in dists:
        (sx, sy), (qx, qy) = d.sample_batch(tasks_per_agent, shots)
        sup_x.append(sx); sup_y.append(sy); qry_x.append(qx); qry_y.append(qy)
    stack = lambda xs: np.stack(xs, axis=0)
    return ((stack(sup_x), stack(sup_y)), (stack(qry_x), stack(qry_y)))


@dataclasses.dataclass
class SineTaskSource(DomainShardedSource):
    """`TaskSource` view of the sine benchmark: the amplitude interval
    [0.1, 5.0] is discretized into ``n_domains`` bands and the bands are
    sharded across agents via ``partition_domains`` — agent k's amplitude
    range is the (contiguous) union of its bands, recovering the paper's
    per-agent sub-intervals while recording which band each task came from.
    A task = one band draw, amplitude uniform inside the band, phase
    ~ U[0, π]; support/query are disjoint draws from the same sinusoid.

    ``holdout_domains`` reserves the top amplitude bands for the unseen
    eval split: agents train on the first ``n_domains - holdout_domains``
    bands and ``eval_sample(split='unseen')`` draws only from the held-out
    tail (recurring-vs-unseen generalization, Fallah et al. 2021).
    """
    K: int = 6
    tasks_per_agent: int = 5
    shots: int = 10
    n_domains: int = 60
    holdout_domains: int = 0
    seed: int = 0
    heterogeneity: str = "amplitude-bands"

    def __post_init__(self):
        self._edges = np.linspace(AMP_LO, AMP_HI, self.n_domains + 1)

    @property
    def n_train_domains(self) -> int:
        return self.n_domains - self.holdout_domains

    def _tasks(self, dom: np.ndarray, rng: np.random.Generator):
        """(support, query) for one batch of band-indexed tasks."""
        T, S = len(dom), self.shots
        amp = rng.uniform(self._edges[dom], self._edges[dom + 1])[:, None, None]
        phase = rng.uniform(PHASE_LO, PHASE_HI, size=(T, 1, 1))
        xs = rng.uniform(X_LO, X_HI, size=(T, 2 * S, 1))
        ys = (amp * np.sin(xs + phase)).astype(np.float32)
        xs = xs.astype(np.float32)
        return ((xs[:, :S], ys[:, :S]), (xs[:, S:], ys[:, S:]))

    def _agent_episode(self, k, domains, rng):
        dom = rng.choice(domains, size=self.tasks_per_agent)
        support, query = self._tasks(dom, rng)
        return support, query, dom

    def eval_sample(self, n_tasks: int, seed: int | None = None,
                    split: str | None = None) -> Episode:
        """Eval tasks: ``split=None`` keeps the paper's protocol (the full
        amplitude interval — adaptation to any sinusoid); 'recurring' draws
        only trained bands, 'unseen' only the held-out tail."""
        rng = self._eval_rng(seed)
        dom = rng.choice(self.eval_domain_pool(split), size=n_tasks)
        support, query = self._tasks(dom, rng)
        return Episode(support, query, domains=dom)
