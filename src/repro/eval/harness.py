"""The one adaptation-at-evaluation-time engine (paper Fig. 2b/2c).

Every consumer that measures how well a launch model *adapts* — the
trainer's in-training eval hook, the post-hoc benchmarks, and the serving
path — goes through this module.  Adaptation itself is
:func:`repro.core.maml.inner_adapt`, the same code path the meta step
differentiates through, so eval semantics track any inner-loop change
(freeze masks, remat, multi-step scan) automatically.

Two layers:

:class:`EvalHarness`
    Bound to ``(loss_fn, inner_lr, inner_steps)``.  ``curves`` is the
    jitted batched adapt-and-measure primitive: per-inner-step query-loss
    curves over a batch of eval tasks (index 0 = zero-shot).  ``evaluate``
    is the full recurring-vs-unseen protocol: draw ``eval_sample``
    episodes from both splits of a :class:`~repro.data.episodes.TaskSource`,
    measure against both the **centroid** and the **per-agent** parameters
    of a ``TrainState``, and report the generalization gap plus the
    network disagreement at eval time.

:class:`EvalReport` / :class:`SplitReport`
    Plain-data results with a JSON-ready ``to_record()`` for the trainer's
    JSONL run log.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diffusion, maml
from repro.data.episodes import EVAL_SPLITS, Episode

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]

__all__ = ["EvalHarness", "EvalReport", "SplitReport", "split_seed"]


def split_seed(seed: int | None, split: str) -> int | None:
    """Derive an independent eval seed per split name.

    ``evaluate`` draws every split from the same base seed; feeding that
    seed to each split's ``eval_sample`` verbatim makes the recurring and
    unseen draws *correlated* (same RNG stream, different domain pools),
    which quietly narrows the generalization-gap estimate.  Mixing the
    split name into the seed decorrelates the streams while staying
    deterministic per (seed, split).  ``None`` passes through (sources
    fall back to their own seed).
    """
    if seed is None:
        return None
    return (seed * 1_000_003 + zlib.crc32(split.encode())) & 0x7FFF_FFFF


@dataclasses.dataclass
class SplitReport:
    """Adaptation-loss curves for one eval split, averaged over tasks.
    Curves have ``inner_steps + 1`` entries; index 0 is zero-shot."""
    split: str
    n_tasks: int
    centroid_curve: np.ndarray        # (steps+1,) centroid launch model
    agent_curve: np.ndarray | None    # (steps+1,) mean over per-agent models

    def to_record(self) -> dict:
        rec = {"n_tasks": self.n_tasks,
               "centroid_curve": [float(x) for x in self.centroid_curve]}
        if self.agent_curve is not None:
            rec["agent_curve"] = [float(x) for x in self.agent_curve]
        return rec


@dataclasses.dataclass
class EvalReport:
    """One EvalHarness pass: per-split adaptation curves + scalars."""
    step: int | None
    splits: dict[str, SplitReport]
    disagreement: float | None = None

    @property
    def generalization_gap(self) -> float | None:
        """Final-adapted unseen loss minus recurring loss (centroid): how
        much worse the launch model adapts to tasks no agent trained on."""
        if not {"recurring", "unseen"} <= set(self.splits):
            return None
        return (float(self.splits["unseen"].centroid_curve[-1])
                - float(self.splits["recurring"].centroid_curve[-1]))

    def to_record(self) -> dict:
        rec: dict[str, Any] = {
            "splits": {name: s.to_record() for name, s in self.splits.items()},
        }
        if self.step is not None:
            rec["step"] = int(self.step)
        if self.disagreement is not None:
            rec["disagreement"] = float(self.disagreement)
        gap = self.generalization_gap
        if gap is not None:
            rec["generalization_gap"] = gap
        return rec


@dataclasses.dataclass
class EvalHarness:
    """Batched adapt-and-measure on ``maml.inner_adapt``.

    ``curves(params, support, query)`` — params one launch model (no agent
    axis), support/query task-leading pytrees — returns ``(n_tasks,
    inner_steps + 1)`` query-loss curves.  ``agent_curves`` vmaps the same
    primitive over a leading agent axis.  Both are jitted once per input
    geometry.  Eval is never differentiated, so adaptation runs
    ``first_order=True`` (a free no-op on the forward path).
    """
    loss_fn: LossFn
    inner_lr: float
    inner_steps: int = 1
    splits: tuple[str, ...] = EVAL_SPLITS

    def __post_init__(self):
        def eval_one(params, support, query):
            def body(p, _):
                p = maml.inner_adapt(self.loss_fn, p, support,
                                     alpha=self.inner_lr, steps=1,
                                     first_order=True)
                return p, self.loss_fn(p, query)

            l0 = self.loss_fn(params, query)
            _, losses = jax.lax.scan(body, params, None,
                                     length=self.inner_steps)
            return jnp.concatenate([l0[None], losses])

        def curves(params, support, query):
            return jax.vmap(lambda s, q: eval_one(params, s, q))(support,
                                                                 query)

        def adapt_states(params, support):
            return jax.vmap(lambda s: maml.inner_adapt(
                self.loss_fn, params, s, alpha=self.inner_lr,
                steps=self.inner_steps, first_order=True))(support)

        self._curves = jax.jit(curves)
        self._agent_curves = jax.jit(jax.vmap(curves, in_axes=(0, None, None)))
        self._adapt_states = jax.jit(adapt_states)
        self._task_loss = jax.jit(jax.vmap(self.loss_fn))

    # -- primitives ----------------------------------------------------------

    def curves(self, params: PyTree, support: Any, query: Any) -> jax.Array:
        """(n_tasks, inner_steps+1) loss curves for one launch model."""
        return self._curves(params, support, query)

    def agent_curves(self, params: PyTree, support: Any, query: Any
                     ) -> jax.Array:
        """(K, n_tasks, inner_steps+1): every agent's own launch model
        measured on the same eval tasks."""
        return self._agent_curves(params, support, query)

    def adapt_states(self, params: PyTree, support: Any) -> PyTree:
        """Adapted parameters, task-stacked: one vmapped ``inner_adapt``
        over a batch of support sets (leading axis = tasks) from one launch
        model.  This is the serving tier's batched-adaptation primitive —
        N concurrent user episodes adapt in a single jitted dispatch
        instead of N sequential ones.  Jitted once per input geometry."""
        return self._adapt_states(params, support)

    def task_loss(self, stacked_params: PyTree, batch: Any) -> jax.Array:
        """(n_tasks,) losses: each task's own adapted params (leading task
        axis, e.g. from :meth:`adapt_states`) on its own batch."""
        return self._task_loss(stacked_params, batch)

    # -- the recurring-vs-unseen protocol ------------------------------------

    def measure(self, params: PyTree, episode: Episode, split: str,
                per_agent: bool = False,
                prepare: Callable[[Any], Any] | None = None) -> SplitReport:
        """One split's report.  ``params`` must carry a leading agent axis
        when ``per_agent``; the centroid is its mean over that axis,
        otherwise ``params`` is used as the centroid directly.  ``prepare``
        post-processes (support, query) — e.g. appends modality stubs."""
        support = jax.tree.map(jnp.asarray, episode.support)
        query = jax.tree.map(jnp.asarray, episode.query)
        if prepare is not None:
            support, query = prepare((support, query))
        centroid = diffusion.centroid(params) if per_agent else params
        cc = np.asarray(self.curves(centroid, support, query)).mean(axis=0)
        ac = None
        if per_agent:
            ac = np.asarray(self.agent_curves(params, support, query)
                            ).mean(axis=(0, 1))
        n_tasks = jax.tree.leaves(support)[0].shape[0]
        return SplitReport(split, int(n_tasks), cc, ac)

    def evaluate(self, state_or_params: Any, source: Any, n_tasks: int,
                 seed: int | None = None, splits: tuple[str, ...] | None = None,
                 prepare: Callable[[Any], Any] | None = None) -> EvalReport:
        """Full protocol: draw ``n_tasks`` ``eval_sample`` episodes from
        each split of ``source``, measure centroid + per-agent curves, and
        report the generalization gap and disagreement-at-eval.

        Accepts a ``TrainState`` (or any object with ``.params`` carrying a
        leading agent axis) or a bare agent-stacked params pytree.
        """
        step = None
        params = state_or_params
        if hasattr(state_or_params, "params"):
            params = state_or_params.params
            s = getattr(state_or_params, "step", None)
            step = int(s) if s is not None else None
        reports = {}
        for split in (self.splits if splits is None else splits):
            ep = source.eval_sample(n_tasks, seed=split_seed(seed, split),
                                    split=split)
            reports[split] = self.measure(params, ep, split, per_agent=True,
                                          prepare=prepare)
        return EvalReport(step, reports,
                          float(diffusion.disagreement(params)))
