"""Adaptation-at-evaluation-time: the shared engine behind the trainer's
in-training eval hook, the post-hoc benchmarks, and serve-time adaptation.

See :mod:`repro.eval.harness` for the :class:`EvalHarness` protocol
(recurring-vs-unseen splits, centroid + per-agent curves, generalization
gap).  ``repro.core.make_eval_fn`` remains as a thin compatibility wrapper
over :meth:`EvalHarness.curves`.
"""
from repro.eval.harness import EvalHarness, EvalReport, SplitReport

__all__ = ["EvalHarness", "EvalReport", "SplitReport"]
