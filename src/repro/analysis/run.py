"""Deviceless lint driver: lower a config × mesh matrix, run every rule.

Builds each (arch × shape × agent-mesh) train step exactly the way
``launch/dryrun.py`` does — AOT ``jit(...).lower(...).compile()`` against
forced host devices, no arrays materialized — then runs the full rule
registry over the compiled HLO and the traced jaxpr and returns a JSON-able
findings report.  ``scripts/lint_xla.py`` is the CLI; ``dryrun.py
--assert-budgets`` delegates its budget block here so there is exactly one
implementation of each invariant.

Entry scripts must force the host device count *before* importing jax
(see ``scripts/lint_xla.py``); this module itself never touches device
state at import.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import numpy as np

from repro.analysis.rules import LintContext, run_rules
from repro.configs import INPUT_SHAPES, get_config
from repro.launch import steps as S
from repro.launch.mesh import make_production_mesh

# Pinned agent-mesh budgets: per-device collective bytes per train step for
# the acceptance configs on make_production_mesh(agents=K) with the
# mesh_sparse_dynamic ring combine on the bf16 wire (the default: these
# archs store bf16 outer state, so resolve_combine_dtype picks the
# u16-bitcast half-width wire).  Measured on this revision, ceiling =
# measured × 1.05.  The collective-budget rule fails a config that exceeds
# its ceiling (TP all-reduces ballooning) or whose combine permute bytes
# leave the deg·shard window — the regression pins for the agent-mesh
# composition.  agents=8 entries are the 3D (agent=8, data=2, model=16)
# mesh; its data axis adds all-gather / resharding traffic the 2D collapse
# never pays, so each carries its own pin.  Re-pin procedure: ANALYSIS.md.
AGENT_MESH_BUDGETS: dict[tuple[str, str, int], int] = {
    ("qwen2-7b", "train_4k", 16): 412_000_000_000,          # meas 3.922e11
    ("qwen2-7b", "train_4k", 8): 497_000_000_000,           # meas 4.729e11
    ("mixtral-8x22b", "train_4k", 16): 2_771_000_000_000,   # meas 2.639e12
    # mixtral's 3D pin is 14× its 2D one: the data axis forces involuntary
    # full rematerialization of the MoE token gathers (bf16 all-gathers of
    # the routed activations — see the spmd_partitioner warnings in the
    # lint log).  Pinned as-is so any further regression is caught; fixing
    # the gather shardings would let this pin drop by an order of
    # magnitude.
    ("mixtral-8x22b", "train_4k", 8): 39_120_000_000_000,   # meas 3.726e13
    ("deepseek-v2-lite-16b", "train_4k", 16): 1_149_000_000_000,  # 1.095e12
    ("deepseek-v2-lite-16b", "train_4k", 8): 5_763_000_000_000,   # 5.489e12
}


def context_for_bundle(
    bundle: Any,
    hlo: str | None = None,
    *,
    jaxpr: Any = None,
    ceiling: int | None = None,
    compile_counts: dict[str, dict] | None = None,
    slack: float = 0.25,
) -> LintContext:
    """Build a :class:`LintContext` from a TrainBundle's own metadata —
    the bridge between the launch layer and the rule registry."""
    md = bundle.lint_metadata()
    return LintContext(
        hlo=hlo,
        jaxpr=jaxpr,
        n_dev=md["n_dev"],
        K=md["K"],
        degree=md["degree"],
        shard_bytes=md["shard_bytes"],
        wire_dtype=md["wire_dtype"],
        emits_permutes=md["emits_permutes"],
        combine_every=md["combine_every"],
        slack=slack,
        budget_ceiling=ceiling,
        expected_aliases=md["expected_aliases"],
        compile_counts=compile_counts,
        extra={"mesh_axes": md["mesh_axes"],
               "combine_backend": md["backend"]},
    )


def _mesh_tag(mesh) -> str:
    return "x".join(
        f"{name[0]}{size}"
        for name, size in zip(mesh.axis_names, mesh.devices.shape,
                              strict=True)
    )


def lint_train_config(
    arch: str,
    shape_name: str = "train_4k",
    *,
    agents: int,
    combine: str | None = "mesh_sparse_dynamic",
    overrides: dict | None = None,
    save_hlo: str | None = None,
) -> dict:
    """Lower one (arch × shape × agent-mesh) train step devicelessly and
    run the full rule registry over it.  Returns a JSON-able record with
    the LintReport under ``"lint"``."""
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if INPUT_SHAPES[shape_name].kind != "train":
        raise ValueError(
            f"lint_train_config lints train steps; shape {shape_name!r} "
            f"is kind {INPUT_SHAPES[shape_name].kind!r}")
    mesh = make_production_mesh(agents=agents)
    t0 = time.time()
    with mesh:
        bundle = S.build_train(cfg, mesh, shape_name,
                               combine_override=combine)
        in_specs = S.input_specs(cfg, shape_name)
        # out_shardings pins the NEW state to the input state's layout —
        # without it XLA may emit a step whose output sharding differs,
        # hiding the combine's data movement from this step (same contract
        # as dryrun.run_one); donation feeds the donation-honored rule.
        jitted = jax.jit(
            bundle.step_fn,
            in_shardings=(bundle.state_shardings, bundle.batch_shardings),
            out_shardings=(bundle.state_shardings, None),
            donate_argnums=(0,),
        )
        lowered = jitted.lower(bundle.state_specs, in_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        hlo = compiled.as_text()
        try:
            jaxpr = jax.make_jaxpr(bundle.step_fn)(bundle.state_specs,
                                                   in_specs)
        except Exception:
            jaxpr = None  # jaxpr rules are best-effort; HLO rules still run
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    ceiling = AGENT_MESH_BUDGETS.get((arch, shape_name, agents))
    ctx = context_for_bundle(bundle, hlo, jaxpr=jaxpr, ceiling=ceiling)
    report = run_rules(ctx)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": _mesh_tag(mesh),
        "devices": int(np.prod(mesh.devices.shape)),
        "combine": ctx.extra["combine_backend"],
        "wire_dtype": ctx.wire_dtype,
        "budget_ceiling": ceiling,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "lint": report.to_json(),
    }


def lint_matrix(
    archs: list[str],
    agents_list: list[int],
    shape_name: str = "train_4k",
    *,
    combine: str | None = "mesh_sparse_dynamic",
    verbose: bool = True,
) -> tuple[list[dict], int]:
    """Lint every arch × agent-mesh cell; returns (records, n_findings)."""
    records: list[dict] = []
    n_findings = 0
    for arch in archs:
        for agents in agents_list:
            rec = lint_train_config(arch, shape_name, agents=agents,
                                    combine=combine)
            records.append(rec)
            lint = rec["lint"]
            n_findings += len(lint["findings"])
            if verbose:
                status = "clean" if lint["ok"] else (
                    f"{len(lint['findings'])} finding(s)")
                print(f"[lint-xla] {arch} × {shape_name} × {rec['mesh']}: "
                      f"{status} "
                      f"(checked {', '.join(lint['checked'])}; "
                      f"compile {rec['compile_s']:.0f}s)")
                for f in lint["findings"]:
                    print(f"  FINDING[{f['rule']}] {f['message']}")
    return records, n_findings
