"""Static analysis over compiled programs: the HLO/jaxpr lint registry.

``repro.analysis.rules`` owns every compiled-program invariant (one rule
per invariant, declaratively registered); ``repro.analysis.run`` lowers
config × mesh matrices devicelessly and runs the registry;
``repro.analysis.hlo`` parses HLO computation graphs.  See ANALYSIS.md
for the rule catalog and conventions.

This package root re-exports the text-only surface and imports no jax.
"""

from repro.analysis.rules import (  # noqa: F401
    RULES,
    CompileCounter,
    Finding,
    LintContext,
    LintReport,
    Rule,
    combine_window,
    register_rule,
    run_rules,
)
