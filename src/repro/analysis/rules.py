"""Declarative rule registry over lowered jaxprs and compiled HLO.

Every compiled-program invariant in the repo lives here, exactly once:

  collective-budget   the agent combine moves deg·shard permute bytes (not
                      K·shard) and the config stays under its pinned
                      per-device collective ceiling
  wire-dtype-leak     a bf16 combine ships u16 on the wire; full-width
                      permute traffic standing in for it is the bug class
                      the u16 bitcast exists to prevent
  conditional-comm    with combine_every > 1, the K×K mixing dot and the
                      combine's permutes are reachable only through a
                      conditional branch — skipped steps pay zero comm
  donation-honored    buffers donated to jit show up as input_output_alias
                      entries; a missing entry is a defensive copy
  retrace-guard       traced steps carry no weak-type python scalars or
                      host callbacks, and jit caches report exactly the
                      expected number of compilations

Rules consume a :class:`LintContext` and return :class:`Finding`s.  The
module imports no jax — jaxprs arrive as objects and are only attribute-
inspected, HLO arrives as text — so rules run in any process on programs
captured elsewhere.  Drivers that *build* contexts live in
:mod:`repro.analysis.run`.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections.abc import Callable, Iterator
from typing import Any

from repro.analysis import hlo as H
from repro.launch.hlo_cost import HloCost


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation. ``detail`` carries the numbers for the JSON
    report; ``message`` is the human line."""

    rule: str
    message: str
    detail: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"rule": self.rule, "message": self.message,
                "detail": self.detail}


@dataclasses.dataclass
class LintContext:
    """Everything a rule may look at for one lowered program.

    Populate only what you have: each rule declares which fields make it
    applicable and is skipped (recorded in ``LintReport.skipped``) when
    they are missing.  ``records`` is scratch output — rules stash their
    measured numbers there even when clean, so drivers can report
    measurements, not just violations.
    """

    hlo: str | None = None
    jaxpr: Any = None  # jax ClosedJaxpr (attribute-inspected only)
    n_dev: int = 1
    K: int = 1
    degree: int | None = None
    shard_bytes: int = 0
    wire_dtype: str | None = None
    emits_permutes: bool = True
    combine_every: int = 1
    slack: float = 0.25
    budget_ceiling: int | None = None
    expected_aliases: int | None = None
    min_alias_fraction: float = 0.9
    compile_counts: dict[str, dict] | None = None
    extra: dict = dataclasses.field(default_factory=dict)
    records: dict = dataclasses.field(default_factory=dict)
    _cost: HloCost | None = dataclasses.field(default=None, repr=False)

    def cost(self) -> HloCost:
        """Memoized HloCost over ``hlo`` (parsing big HLO once, not once
        per rule)."""
        if self._cost is None:
            if self.hlo is None:
                raise ValueError("LintContext has no HLO text")
            self._cost = HloCost(self.hlo, n_dev=self.n_dev)
        return self._cost


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    applies: Callable[[LintContext], bool]
    check: Callable[[LintContext], list[Finding]]


RULES: dict[str, Rule] = {}


def register_rule(
    name: str, description: str, applies: Callable[[LintContext], bool]
) -> Callable[[Callable[[LintContext], list[Finding]]], Rule]:
    def deco(fn: Callable[[LintContext], list[Finding]]) -> Rule:
        rule = Rule(name, description, applies, fn)
        RULES[name] = rule
        return rule

    return deco


@dataclasses.dataclass
class LintReport:
    findings: list[Finding]
    checked: list[str]
    skipped: list[str]
    records: dict

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "findings": [f.to_json() for f in self.findings],
            "checked": self.checked,
            "skipped": self.skipped,
            "records": self.records,
        }


def run_rules(
    ctx: LintContext, only: list[str] | None = None
) -> LintReport:
    """Run every registered (or selected) rule whose preconditions the
    context satisfies."""
    findings: list[Finding] = []
    checked: list[str] = []
    skipped: list[str] = []
    names = list(RULES) if only is None else list(only)
    for name in names:
        rule = RULES[name]
        if not rule.applies(ctx):
            skipped.append(name)
            continue
        checked.append(name)
        findings.extend(rule.check(ctx))
    return LintReport(findings, checked, skipped, dict(ctx.records))


# ---------------------------------------------------------------------------
# collective-budget — deg·shard window + pinned ceiling
# ---------------------------------------------------------------------------


def combine_window(
    hlo: str | None = None,
    n_dev: int = 1,
    *,
    degree: int,
    shard_bytes: int,
    slack: float = 0.25,
    wire_dtype: str | None = None,
    cost: HloCost | None = None,
) -> dict:
    """Measure the agent combine's wire cost in post-SPMD HLO.

    The ppermute combine must move exactly ``degree`` rounds of one
    per-device parameter shard: total collective-permute wire bytes in
    ``[deg·shard, (1+slack)·deg·shard]``.  The lower bound catches a
    combine that silently stopped being lowered; the upper bound catches
    K-scaling regressions (dense all-gather re-emerging: K·shard ≫
    (1+slack)·deg·shard for any sparse graph) while absorbing small
    GSPMD resharding permutes.  ``shard_bytes`` must already be sized at
    the wire dtype (``tree_shard_bytes(..., elem_bytes=wire_elem_bytes)``)
    — a bf16 wire halves the whole window, so this check also catches a
    combine that silently fell back to the f32 wire.

    ``wire_dtype='bfloat16'``: the combine ships its payload bitcast to
    u16 (see core/diffusion.py's wire-format contract) and is the only
    u16 traffic in the program, so the window is applied to the u16
    permute bytes alone.  On meshes with a data axis this is what makes
    the check usable at all: activation-resharding permutes (bf16/f32)
    can dwarf the combine, but they can never masquerade as its wire.
    Other wire dtypes share their permute dtype with resharding traffic,
    so the window falls back to total permute bytes.

    Returns a record with ``ok`` plus the numbers; raises nothing —
    callers decide how loud to be.  This is the one implementation behind
    both the ``collective-budget`` rule and the legacy
    ``hlo_cost.agent_combine_check`` entry point.
    """
    if cost is None:
        if hlo is None:
            raise ValueError("combine_window needs hlo text or an HloCost")
        cost = HloCost(hlo, n_dev=n_dev)
    coll = cost.collectives()
    cp = coll["per_op"].get(
        "collective-permute",
        {"count": 0, "bytes": 0, "wire_bytes": 0, "by_dtype": {}},
    )
    if wire_dtype == "bfloat16":
        measured = cp.get("by_dtype", {}).get("u16", 0)
    else:
        measured = cp["wire_bytes"]
    expected = degree * shard_bytes
    ok = expected <= measured <= (1 + slack) * expected
    rec = {
        "degree": degree,
        "param_shard_bytes": shard_bytes,
        "expected_permute_bytes": expected,
        "permute_bytes": measured,
        "all_permute_bytes": cp["wire_bytes"],
        "permute_count": cp["count"],
        "total_collective_bytes": coll["total_bytes"],
        "ok": bool(ok),
    }
    if wire_dtype is not None:
        rec["wire_dtype"] = wire_dtype
    return rec


@register_rule(
    "collective-budget",
    "combine permute bytes sit in the deg·shard window and total "
    "collective bytes stay under the pinned per-config ceiling",
    lambda ctx: ctx.hlo is not None
    and ctx.degree is not None
    and (ctx.shard_bytes > 0 or ctx.budget_ceiling is not None),
)
def _collective_budget(ctx: LintContext) -> list[Finding]:
    rec = combine_window(
        cost=ctx.cost(),
        degree=ctx.degree or 0,
        shard_bytes=ctx.shard_bytes,
        slack=ctx.slack,
        wire_dtype=ctx.wire_dtype,
    )
    ctx.records["collective-budget"] = rec
    findings = []
    if not rec["ok"]:
        lo = rec["expected_permute_bytes"]
        hi = (1 + ctx.slack) * lo
        side = "below" if rec["permute_bytes"] < lo else "above"
        findings.append(
            Finding(
                "collective-budget",
                f"combine collective-permute bytes "
                f"{rec['permute_bytes']:.3e} {side} the deg·shard window "
                f"[{lo:.3e}, {hi:.3e}] (deg={rec['degree']}, "
                f"shard={rec['param_shard_bytes']:.3e} B) — the ring "
                f"combine must move deg per-agent shards, not K",
                dict(rec),
            )
        )
    if ctx.budget_ceiling is not None:
        total = rec["total_collective_bytes"]
        if total > ctx.budget_ceiling:
            findings.append(
                Finding(
                    "collective-budget",
                    f"total collective bytes {total:.3e} exceed the "
                    f"pinned ceiling {ctx.budget_ceiling:.3e} — TP/FSDP "
                    f"collectives regressed (or re-pin the budget if the "
                    f"change is intentional)",
                    {"total_collective_bytes": total,
                     "ceiling": ctx.budget_ceiling},
                )
            )
    return findings


# ---------------------------------------------------------------------------
# wire-dtype-leak — bf16 combine payload must travel as u16
# ---------------------------------------------------------------------------


@register_rule(
    "wire-dtype-leak",
    "a bf16 combine's permute traffic is u16-bitcast; full-width f32/bf16 "
    "permutes carrying the payload instead are a leak",
    lambda ctx: ctx.hlo is not None
    and ctx.wire_dtype == "bfloat16"
    and ctx.emits_permutes
    and (ctx.degree or 0) > 0
    and ctx.shard_bytes > 0,
)
def _wire_dtype_leak(ctx: LintContext) -> list[Finding]:
    cp = ctx.cost().collectives()["per_op"].get(
        "collective-permute",
        {"count": 0, "wire_bytes": 0, "by_dtype": {}},
    )
    by_dtype = dict(cp.get("by_dtype", {}))
    u16 = by_dtype.get("u16", 0)
    expected = (ctx.degree or 0) * ctx.shard_bytes
    ctx.records["wire-dtype-leak"] = {
        "u16_permute_bytes": u16,
        "expected_wire_bytes": expected,
        "permute_by_dtype": by_dtype,
    }
    if u16 >= expected:
        return []
    if u16 == 0:
        msg = (
            f"no u16 collective-permute traffic at all — the bf16 combine "
            f"payload is travelling at full width (permute bytes by "
            f"dtype: {by_dtype or 'none'})"
        )
    else:
        msg = (
            f"u16 collective-permute bytes {u16:.3e} below the combine's "
            f"wire size deg·shard = {expected:.3e} — part of the bf16 "
            f"payload leaked to a wider dtype (by dtype: {by_dtype})"
        )
    return [
        Finding(
            "wire-dtype-leak",
            msg,
            {"u16_permute_bytes": u16, "expected_wire_bytes": expected,
             "permute_by_dtype": by_dtype},
        )
    ]


# ---------------------------------------------------------------------------
# conditional-comm — combine_every > 1 gates all combine compute + comm
# ---------------------------------------------------------------------------


def _marker_lines(lines: list[str], K: int, wire_dtype: str | None) -> list[str]:
    """Instructions that implement the combine: the K×K mixing dot, and
    (on a bf16 wire) u16 collective-permutes — nothing else in the
    program produces either."""
    dot_re = re.compile(rf"(?:f32|bf16|f64)\[{K},{K}\]")
    out = []
    for line in lines:
        if " dot(" in line and dot_re.search(line):
            out.append(line)
        elif (
            wire_dtype == "bfloat16"
            and "collective-permute" in line
            and "u16[" in line
        ):
            out.append(line)
    return out


@register_rule(
    "conditional-comm",
    "with combine_every > 1 the K×K combine dot and the combine's "
    "permutes are reachable only through a conditional branch",
    lambda ctx: ctx.hlo is not None and ctx.combine_every > 1 and ctx.K > 1,
)
def _conditional_comm(ctx: LintContext) -> list[Finding]:
    comps, entry = H.parse_computations(ctx.hlo or "")
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    marked = {
        name
        for name, lines in comps.items()
        if _marker_lines(lines, ctx.K, ctx.wire_dtype)
    }
    findings: list[Finding] = []
    if not marked:
        return [
            Finding(
                "conditional-comm",
                f"combine_every={ctx.combine_every} but no combine markers "
                f"(f32[{ctx.K},{ctx.K}] dot / wire permutes) anywhere in "
                f"the module — the combine was not lowered at all",
                {"K": ctx.K, "combine_every": ctx.combine_every},
            )
        ]
    uncond = H.reachable(comps, entry, include_branches=False)
    leaked = sorted(uncond & marked)
    if leaked:
        findings.append(
            Finding(
                "conditional-comm",
                f"combine instructions run unconditionally (reachable "
                f"from ENTRY without crossing a conditional branch) in "
                f"computations {leaked} — skipped steps would still pay "
                f"the combine",
                {"computations": leaked},
            )
        )
    gated = False
    for line in H.conditional_lines(comps):
        hot = [
            b
            for b in H.conditional_branches(line)
            if H.reachable(comps, b) & marked
        ]
        if len(hot) == 1:
            gated = True
        elif len(hot) > 1:
            findings.append(
                Finding(
                    "conditional-comm",
                    f"a conditional reaches combine instructions through "
                    f"{len(hot)} of its branches ({hot}) — both arms pay "
                    f"the combine, so the gate is vacuous",
                    {"branches": hot},
                )
            )
    if not gated and not leaked:
        findings.append(
            Finding(
                "conditional-comm",
                "combine instructions exist but no conditional gates "
                "them through exactly one branch",
                {"marked": sorted(marked)},
            )
        )
    return findings


# ---------------------------------------------------------------------------
# donation-honored — donated buffers must alias, not copy
# ---------------------------------------------------------------------------


@register_rule(
    "donation-honored",
    "buffers donated to jit appear as input_output_alias entries; a "
    "donated buffer without one forced a defensive copy",
    lambda ctx: ctx.hlo is not None and ctx.expected_aliases is not None,
)
def _donation_honored(ctx: LintContext) -> list[Finding]:
    n = H.alias_entries(ctx.hlo or "")
    expected = int(ctx.expected_aliases or 0)
    need = math.ceil(expected * ctx.min_alias_fraction)
    ctx.records["donation-honored"] = {
        "alias_entries": n,
        "donated_leaves": expected,
        "required": need,
    }
    if n >= need:
        return []
    return [
        Finding(
            "donation-honored",
            f"only {n} of {expected} donated buffers are aliased to "
            f"outputs (need ≥ {need}) — XLA inserted defensive copies "
            f"instead of reusing the donated memory",
            {"alias_entries": n, "donated_leaves": expected,
             "required": need},
        )
    ]


# ---------------------------------------------------------------------------
# retrace-guard — no weak-type scalars / host callbacks; jit caches stay 1
# ---------------------------------------------------------------------------

_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback", "callback")


def _walk_eqns(jaxpr: Any) -> Iterator[Any]:
    """Every eqn in a (Closed)Jaxpr, recursing into sub-jaxprs held in
    eqn params (cond branches, scan bodies, pjit calls, ...)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in getattr(inner, "eqns", []):
        yield eqn
        for v in eqn.params.values():
            for sub in v if isinstance(v, (list, tuple)) else (v,):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    yield from _walk_eqns(sub)


@register_rule(
    "retrace-guard",
    "traced steps carry no weak-type python-scalar inputs or host "
    "callbacks, and jit caches report exactly the expected compiles",
    lambda ctx: ctx.jaxpr is not None or ctx.compile_counts is not None,
)
def _retrace_guard(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    if ctx.jaxpr is not None:
        inner = getattr(ctx.jaxpr, "jaxpr", ctx.jaxpr)
        weak = [
            str(v)
            for v in getattr(inner, "invars", [])
            if getattr(getattr(v, "aval", None), "weak_type", False)
        ]
        if weak:
            findings.append(
                Finding(
                    "retrace-guard",
                    f"traced step takes weak-typed inputs {weak} — a "
                    f"python scalar leaked into the trace, so every new "
                    f"value retriggers compilation; pass a jnp array or "
                    f"close over the constant",
                    {"weak_invars": weak},
                )
            )
        hostcalls = sorted(
            {
                eqn.primitive.name
                for eqn in _walk_eqns(ctx.jaxpr)
                if any(eqn.primitive.name.startswith(p)
                       for p in _CALLBACK_PRIMS)
            }
        )
        if hostcalls:
            findings.append(
                Finding(
                    "retrace-guard",
                    f"traced step contains host callbacks {hostcalls} — "
                    f"each dispatch round-trips to python, defeating the "
                    f"dispatch-free superstep driver",
                    {"callbacks": hostcalls},
                )
            )
    for name, counts in (ctx.compile_counts or {}).items():
        compiles = counts.get("compiles")
        expected = counts.get("expected", 1)
        if compiles is None:
            continue  # jax build without a readable cache size
        if compiles > expected:
            findings.append(
                Finding(
                    "retrace-guard",
                    f"{name} compiled {compiles}× across "
                    f"{counts.get('dispatches', '?')} dispatches "
                    f"(expected {expected}) — a shape/dtype/weak-type "
                    f"mismatch is forcing retraces",
                    dict(counts, fn=name),
                )
            )
    return findings


class CompileCounter:
    """Read a jitted function's compilation-cache size — the
    jit-cache-miss counter behind retrace-guard's compile assertions.

    ``count()`` returns None on jax builds without a readable cache size
    (callers must treat None as "unknown", not zero).
    """

    def __init__(self, jitted: Any):
        self._jitted = jitted

    def count(self) -> int | None:
        getter = getattr(self._jitted, "_cache_size", None)
        if getter is None:
            return None
        try:
            return int(getter())
        except Exception:
            return None
