"""Computation-graph helpers over post-optimization HLO text.

The rule registry (:mod:`repro.analysis.rules`) reasons about *structure* —
which computations a program can reach unconditionally, which only through a
conditional branch, and which input buffers the module aliases to outputs.
This module owns that parsing; per-instruction cost accounting stays in
:mod:`repro.launch.hlo_cost`.

Everything here is pure text analysis: no jax import, no device state —
rules can run in any process on HLO captured elsewhere.
"""

from __future__ import annotations

import re

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
# Edges that always execute when the caller executes (while bodies and
# conditions run on every iteration; calls/fusions run inline) ...
_UNCOND_CALL_RE = re.compile(
    r"(?:calls=|body=|condition=|to_apply=)%?([\w.\-]+)"
)
# ... vs. edges that execute only when their branch is selected.
_BRANCH_RE = re.compile(
    r"(?:branch_computations=\{([^}]*)\}"
    r"|true_computation=%?([\w.\-]+)"
    r"|false_computation=%?([\w.\-]+))"
)


def parse_computations(hlo: str) -> tuple[dict[str, list[str]], str | None]:
    """``(computations, entry_name)``: each computation's instruction lines
    (stripped), plus the name of the ENTRY computation (``None`` when the
    text has no ENTRY marker)."""
    comps: dict[str, list[str]] = {}
    entry: str | None = None
    current: str | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        m = _COMP_RE.match(line.strip())
        if m and line.endswith("{"):
            current = m.group(1)
            comps[current] = []
            if line.lstrip().startswith("ENTRY"):
                entry = current
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line.strip())
    return comps, entry


def conditional_branches(line: str) -> list[str]:
    """Branch computation names of one ``conditional(...)`` instruction."""
    branches: list[str] = []
    for m in _BRANCH_RE.finditer(line):
        if m.group(1):
            branches += [b.strip().lstrip("%") for b in m.group(1).split(",")]
        else:
            branches.append((m.group(2) or m.group(3)).strip())
    return branches


def reachable(
    comps: dict[str, list[str]],
    root: str,
    *,
    include_branches: bool = True,
) -> set[str]:
    """Computations reachable from ``root`` through call edges.

    ``include_branches=False`` follows only the edges that execute whenever
    the caller executes (calls, fusions, while bodies/conditions) and stops
    at conditional branches — the result is the set of computations the
    program runs *unconditionally*, which is exactly what the
    ``conditional-comm`` rule needs to prove a combine is gated.
    """
    seen, frontier = {root}, [root]
    while frontier:
        c = frontier.pop()
        for ins in comps.get(c, []):
            callees = list(_UNCOND_CALL_RE.findall(ins))
            if include_branches:
                callees += conditional_branches(ins)
            for callee in callees:
                if callee in comps and callee not in seen:
                    seen.add(callee)
                    frontier.append(callee)
    return seen


def conditional_lines(comps: dict[str, list[str]]) -> list[str]:
    """Every ``conditional(...)`` instruction in the module."""
    return [
        line
        for body in comps.values()
        for line in body
        if re.search(r"\bconditional\(", line)
    ]


def alias_entries(hlo: str) -> int:
    """Number of ``input_output_alias`` entries the module header declares.

    XLA records one entry per donated buffer it could actually alias to an
    output; a donated buffer that forced a defensive copy simply has no
    entry — so this count against the donated-leaf count is the
    donation-honored check.
    """
    start = hlo.find("input_output_alias={")
    if start < 0:
        return 0
    i = hlo.index("{", start)
    depth, j = 0, i
    while j < len(hlo):
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    body = hlo[i : j + 1]
    return len(re.findall(r":\s*\(", body))
