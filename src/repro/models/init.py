"""Parameter specs with named logical axes.

A model is described by a pytree of :class:`Spec` leaves.  ``materialize``
turns the tree into concrete arrays; ``axes_tree`` extracts the logical axis
names which ``sharding/rules.py`` maps onto mesh axes.  This mirrors the
logical-axis-rules approach of production JAX frameworks (MaxText, T5X)
without pulling in flax.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class Spec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]   # logical name per dim (len == len(shape))
    init: str = "normal"           # normal | zeros | ones | fan_in | embed
    scale: float = 1.0

    def __repr__(self):  # keep pytree prints short
        return f"Spec{self.shape}"


def _is_spec(x) -> bool:
    return isinstance(x, Spec)


def stack_specs(tree: PyTree, n: int, axis_name: str = "layers") -> PyTree:
    """Prepend a stacking dim of size n (for lax.scan'd layer stacks)."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        tree, is_leaf=_is_spec)


def _init_leaf(key: jax.Array, spec: Spec, dtype) -> jax.Array:
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, shape, jnp.float32) * 0.02 * spec.scale
                ).astype(dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, shape, jnp.float32) * spec.scale
                ).astype(dtype)
    if spec.init == "fan_in":
        fan_in = int(np.prod(shape[:-1])) if len(shape) > 1 else shape[0]
        std = spec.scale / max(1.0, np.sqrt(fan_in))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    raise ValueError(spec.init)


def materialize(tree: PyTree, key: jax.Array, dtype=jnp.float32) -> PyTree:
    """Deterministically initialize every Spec leaf (stable key per path)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def abstract(tree: PyTree, dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        tree, is_leaf=_is_spec)


def axes_tree(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=_is_spec)


def count_params(tree: PyTree) -> int:
    return int(sum(np.prod(s.shape) for s in
                   jax.tree.leaves(tree, is_leaf=_is_spec)))


def with_agent_axis(tree: PyTree, K: int) -> PyTree:
    """Stack K per-agent copies: leading 'agent' logical axis."""
    return jax.tree.map(
        lambda s: Spec((K,) + s.shape, ("agent",) + s.axes, s.init, s.scale),
        tree, is_leaf=_is_spec)
