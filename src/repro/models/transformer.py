"""Model assembly: decoder LMs, MoE, hybrid (Jamba-style), enc-dec (Whisper),
VLM (Llama-3.2-vision-style) — all from one segment/period abstraction.

A model is a list of **segments**; each segment scans ``n`` repeats of a
**period** (a short list of heterogeneous blocks).  ``lax.scan`` over the
stacked per-period parameters keeps the HLO size O(period), not O(depth) —
essential for 100-layer models compiled on a 512-device mesh.

  dense LM      [Segment(n=L,  period=(attn+mlp,))]
  mixtral       [Segment(n=56, period=(attn+moe,))]
  deepseek      [Segment(n=1, period=(mla+mlp,)), Segment(n=26, period=(mla+moe,))]
  mamba2        [Segment(n=24, period=(mamba,))]
  jamba         [Segment(n=9,  period=(attn+mlp, mamba+moe, mamba+mlp, mamba+moe,
                                       mamba+mlp, mamba+moe, mamba+mlp, mamba+moe))]
  llama-vision  [Segment(n=20, period=(self+mlp ×4, cross+mlp))]
  whisper       encoder [Segment(n=32, period=(enc,))] +
                decoder [Segment(n=32, period=(self+cross+mlp,))]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.init import Spec, materialize, stack_specs

PyTree = Any


@dataclasses.dataclass(frozen=True)
class BlockDesc:
    mixer: str          # attn | attn_nc (non-causal) | mla | mamba | cross
    ffn: str            # dense | moe | none


@dataclasses.dataclass(frozen=True)
class Segment:
    n: int
    period: tuple[BlockDesc, ...]


# ---------------------------------------------------------------------------
# Block specs / apply / decode
# ---------------------------------------------------------------------------

def block_specs(cfg: ArchConfig, desc: BlockDesc) -> PyTree:
    p: dict[str, Any] = {"norm1": L.norm_specs(cfg)}
    if desc.mixer in ("attn", "attn_nc"):
        p["attn"] = L.attention_specs(cfg)
    elif desc.mixer == "mla":
        p["mla"] = L.mla_specs(cfg)
    elif desc.mixer == "mamba":
        p["mamba"] = L.mamba2_specs(cfg)
    elif desc.mixer == "cross":
        p["cross"] = L.attention_specs(cfg, cross=True)
        p["gate"] = Spec((), (), "zeros")       # llama-3.2 gated cross-attn
    if desc.ffn != "none":
        p["norm2"] = L.norm_specs(cfg)
        p["ffn"] = L.moe_specs(cfg) if desc.ffn == "moe" else L.mlp_specs(cfg)
    return p


def block_apply(cfg: ArchConfig, desc: BlockDesc, p: PyTree, x: jax.Array,
                positions: jax.Array, aux: dict[str, jax.Array]) -> jax.Array:
    h = L.norm_apply(p["norm1"], x)
    if desc.mixer == "attn":
        x = x + L.attention_apply(p["attn"], cfg, h, positions, causal=True)
    elif desc.mixer == "attn_nc":
        x = x + L.attention_apply(p["attn"], cfg, h, positions, causal=False)
    elif desc.mixer == "mla":
        x = x + L.mla_apply(p["mla"], cfg, h, positions)
    elif desc.mixer == "mamba":
        x = x + L.mamba2_apply(p["mamba"], cfg, h)
    elif desc.mixer == "cross":
        y = L.attention_apply(p["cross"], cfg, h, positions, causal=False,
                              kv_x=aux["enc"])
        x = x + jnp.tanh(p["gate"]) * y
    if desc.ffn != "none":
        h = L.norm_apply(p["norm2"], x)
        out = (L.moe_apply(p["ffn"], cfg, h) if desc.ffn == "moe"
               else L.mlp_apply(p["ffn"], h))
        x = x + out
    return x


def block_cache_specs(cfg: ArchConfig, desc: BlockDesc, batch: int,
                      cache_len: int) -> PyTree:
    """Logical (shape, axes) Spec tree for this block's decode state."""
    if desc.mixer in ("attn", "attn_nc"):
        C = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        kv = Spec((batch, C, cfg.num_kv_heads, cfg.head_dim),
                  ("batch", "seq", "kv_heads", "head_dim"), "zeros")
        return {"k": kv, "v": kv}
    if desc.mixer == "mla":
        return {"ckv": Spec((batch, cache_len, cfg.kv_lora_rank),
                            ("batch", "seq", "kv_lora"), "zeros"),
                "kr": Spec((batch, cache_len, cfg.qk_rope_dim),
                           ("batch", "seq", None), "zeros")}
    if desc.mixer == "mamba":
        H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
        ch = H * P + 2 * G * N
        return {"conv": Spec((batch, cfg.ssm_conv - 1, ch),
                             ("batch", None, None), "zeros"),
                "ssm": Spec((batch, H, P, N),
                            ("batch", "ssm_head", "ssm_dim", "ssm_state"), "zeros")}
    if desc.mixer == "cross":
        T = cfg.num_patches or cfg.encoder_frames
        kv = Spec((batch, T, cfg.num_kv_heads, cfg.head_dim),
                  ("batch", None, "kv_heads", "head_dim"), "zeros")
        return {"ck": kv, "cv": kv}
    return {}


def block_decode(cfg: ArchConfig, desc: BlockDesc, p: PyTree, cache: PyTree,
                 x: jax.Array, pos: jax.Array) -> tuple[jax.Array, PyTree]:
    h = L.norm_apply(p["norm1"], x)
    if desc.mixer in ("attn", "attn_nc"):
        y, ck, cv = L.attention_decode(p["attn"], cfg, h, pos,
                                       cache["k"], cache["v"])
        x, cache = x + y, {"k": ck, "v": cv}
    elif desc.mixer == "mla":
        y, ckv, kr = L.mla_decode(p["mla"], cfg, h, pos,
                                  cache["ckv"], cache["kr"])
        x, cache = x + y, {"ckv": ckv, "kr": kr}
    elif desc.mixer == "mamba":
        y, conv, ssm = L.mamba2_decode(p["mamba"], cfg, h,
                                       cache["conv"], cache["ssm"])
        x, cache = x + y, {"conv": conv, "ssm": ssm}
    elif desc.mixer == "cross":
        y = L.cross_attention_decode(p["cross"], cfg, h,
                                     cache["ck"], cache["cv"])
        x = x + jnp.tanh(p["gate"]) * y
    if desc.ffn != "none":
        h = L.norm_apply(p["norm2"], x)
        out = (L.moe_apply(p["ffn"], cfg, h) if desc.ffn == "moe"
               else L.mlp_apply(p["ffn"], h))
        x = x + out
    return x, cache


# ---------------------------------------------------------------------------
# Segment plans per architecture family
# ---------------------------------------------------------------------------

def segment_plan(cfg: ArchConfig) -> list[Segment]:
    t = cfg.arch_type
    if t == "ssm":
        return [Segment(cfg.num_layers, (BlockDesc("mamba", "none"),))]
    if t == "hybrid":
        per = [BlockDesc("attn", "dense")]
        for i in range(1, cfg.attn_every):
            ffn = "moe" if (cfg.num_experts and i % cfg.moe_every == cfg.moe_offset) else "dense"
            per.append(BlockDesc("mamba", ffn))
        return [Segment(cfg.num_layers // cfg.attn_every, tuple(per))]
    if t == "vlm":
        k = cfg.cross_attn_every
        per = tuple([BlockDesc("attn", "dense")] * (k - 1)
                    + [BlockDesc("cross", "dense")])
        return [Segment(cfg.num_layers // k, per)]
    if t == "moe" and cfg.use_mla:  # deepseek
        segs = []
        if cfg.first_dense_layers:
            segs.append(Segment(cfg.first_dense_layers,
                                (BlockDesc("mla", "dense"),)))
        segs.append(Segment(cfg.num_layers - cfg.first_dense_layers,
                            (BlockDesc("mla", "moe"),)))
        return segs
    if t == "moe":
        return [Segment(cfg.num_layers, (BlockDesc("attn", "moe"),))]
    # dense / audio decoder
    return [Segment(cfg.num_layers, (BlockDesc("attn", "dense"),))]


def decoder_cross_plan(cfg: ArchConfig) -> list[Segment]:
    """Whisper decoder: self-attn + cross-attn + mlp per layer."""
    return [Segment(cfg.num_layers,
                    (BlockDesc("attn", "none"), BlockDesc("cross", "dense")))]


def encoder_plan(cfg: ArchConfig) -> list[Segment]:
    return [Segment(cfg.encoder_layers, (BlockDesc("attn_nc", "dense"),))]


# ---------------------------------------------------------------------------
# Segment-level specs / apply / decode (lax.scan over stacked period params)
# ---------------------------------------------------------------------------

def segment_specs(cfg: ArchConfig, seg: Segment) -> PyTree:
    return tuple(stack_specs(block_specs(cfg, d), seg.n) for d in seg.period)


def segment_apply(cfg: ArchConfig, seg: Segment, params: PyTree, x: jax.Array,
                  positions: jax.Array, aux: dict,
                  constrain=None) -> jax.Array:
    constrain = constrain or (lambda h: h)

    # remat_span groups `span` periods per checkpoint region: the scan then
    # saves only every span-th residual (1/span of activation HBM) and the
    # backward re-runs at most span periods.
    span = max(1, min(cfg.remat_span, seg.n))
    while seg.n % span:
        span -= 1

    def body(h, group_params):
        h = constrain(h)   # pin batch sharding inside the scan: the layer
        for i in range(span):                           # residual stack
            layer_params = (group_params if span == 1 else
                            jax.tree.map(lambda a: a[i], group_params))
            for desc, p in zip(seg.period, layer_params):
                h = block_apply(cfg, desc, p, h, positions, aux)
        return constrain(h), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if span > 1:
        params = jax.tree.map(
            lambda a: a.reshape((seg.n // span, span) + a.shape[1:]), params)
    x, _ = jax.lax.scan(body, x, params)
    return x


def segment_cache_specs(cfg: ArchConfig, seg: Segment, batch: int,
                        cache_len: int) -> PyTree:
    return tuple(stack_specs(block_cache_specs(cfg, d, batch, cache_len), seg.n)
                 for d in seg.period)


def segment_decode(cfg: ArchConfig, seg: Segment, params: PyTree,
                   cache: PyTree, x: jax.Array, pos: jax.Array
                   ) -> tuple[jax.Array, PyTree]:
    def body(h, inp):
        layer_params, layer_cache = inp
        new_cache = []
        for desc, p, c in zip(seg.period, layer_params, layer_cache):
            h, nc = block_decode(cfg, desc, p, c, h, pos)
            new_cache.append(nc)
        return h, tuple(new_cache)

    x, new_cache = jax.lax.scan(body, x, (params, cache))
    return x, new_cache


# ---------------------------------------------------------------------------
# Full models
# ---------------------------------------------------------------------------

class Model:
    """Bundles specs + pure functions for one architecture."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.plan = segment_plan(cfg)
        self.is_encdec = cfg.arch_type == "audio"
        if self.is_encdec:
            self.plan = decoder_cross_plan(cfg)
            self.enc_plan = encoder_plan(cfg)
        # Optional NamedSharding for (batch, seq, d_model) activations.
        # Set by launch/steps.py for pod-placement archs: without it GSPMD
        # follows the TP params and silently replicates the batch dim over
        # the data axis (measured 16× per-device FLOPs on mixtral/jamba).
        self.act_sharding = None

    def _constrain(self, x: jax.Array) -> jax.Array:
        if self.act_sharding is not None:
            return jax.lax.with_sharding_constraint(x, self.act_sharding)
        return x

    # -- specs ---------------------------------------------------------------
    def specs(self) -> PyTree:
        cfg = self.cfg
        V, d = cfg.padded_vocab, cfg.d_model
        p: dict[str, Any] = {
            "embed": Spec((V, d), ("vocab", "embed"), "embed", 0.02),
            "final_norm": L.norm_specs(cfg),
            "head": Spec((d, V), ("embed", "vocab"), "fan_in"),
            "segments": [segment_specs(cfg, s) for s in self.plan],
        }
        if self.is_encdec:
            p["encoder"] = {
                "segments": [segment_specs(cfg, s) for s in self.enc_plan],
                "final_norm": L.norm_specs(cfg),
            }
        if cfg.arch_type == "vlm":
            # stub projector: patch embeddings (already d_model) -> d_model
            p["vision_proj"] = Spec((d, d), ("embed", None), "fan_in")
        return p

    def init(self, key: jax.Array, dtype=jnp.float32) -> PyTree:
        return materialize(self.specs(), key, dtype)

    # -- encoder (whisper stub frontend: frames are precomputed embeddings) --
    def encode(self, params: PyTree, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        F = frames.shape[1]
        pos_tab = jnp.asarray(L.sinusoidal_positions(F, cfg.d_model),
                              frames.dtype)
        x = frames + pos_tab[None]
        positions = jnp.arange(F)[None]
        for seg, sp in zip(self.enc_plan, params["encoder"]["segments"]):
            x = segment_apply(cfg, seg, sp, x, positions, {},
                              constrain=self._constrain)
        return L.norm_apply(params["encoder"]["final_norm"], x)

    def _aux(self, params: PyTree, batch: dict) -> dict:
        cfg = self.cfg
        if self.is_encdec:
            return {"enc": self.encode(params, batch["encoder_frames"])}
        if cfg.arch_type == "vlm":
            return {"enc": batch["image_patches"] @ params["vision_proj"]}
        return {}

    # -- forward -------------------------------------------------------------
    def forward(self, params: PyTree, batch: dict) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"][tokens]
        if not cfg.use_rope:  # absolute sinusoidal positions (whisper decoder)
            x = x + jnp.asarray(L.sinusoidal_positions(S, cfg.d_model),
                                x.dtype)[None]
        positions = jnp.arange(S)[None]
        aux = self._aux(params, batch)
        x = self._constrain(x)
        for seg, sp in zip(self.plan, params["segments"]):
            x = segment_apply(cfg, seg, sp, x, positions, aux,
                              constrain=self._constrain)
        x = L.norm_apply(params["final_norm"], x)
        return x @ params["head"]

    def loss_fn(self, params: PyTree, batch: dict) -> jax.Array:
        logits = self.forward(params, batch).astype(jnp.float32)
        labels = batch["labels"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    # -- decode --------------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int) -> PyTree:
        return [segment_cache_specs(self.cfg, s, batch, cache_len)
                for s in self.plan]

    def init_cache(self, batch: int, cache_len: int, dtype=jnp.bfloat16,
                   params: PyTree | None = None,
                   enc: jax.Array | None = None) -> PyTree:
        """Zero caches; if (params, enc) given, prefill cross-attn K/V."""
        cache = materialize(self.cache_specs(batch, cache_len),
                            jax.random.key(0), dtype)
        if enc is not None and params is not None:
            cache = self._fill_cross(params, cache, enc, dtype)
        return cache

    def _fill_cross(self, params, cache, enc, dtype):
        for si, (seg, sp) in enumerate(zip(self.plan, params["segments"])):
            for pi, desc in enumerate(seg.period):
                if desc.mixer != "cross":
                    continue
                def per_layer(p):
                    k, v = L.cross_kv(p["cross"], enc)
                    return k.astype(dtype), v.astype(dtype)
                ks, vs = jax.vmap(per_layer)(sp[pi])
                cache[si][pi]["ck"] = ks
                cache[si][pi]["cv"] = vs
        return cache

    def decode_step(self, params: PyTree, cache: PyTree, token: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, PyTree]:
        """One decode step.  token: (B,1) int32, pos: (B,) int32.
        Returns (logits (B,1,V), new cache)."""
        cfg = self.cfg
        x = params["embed"][token]
        if not cfg.use_rope:
            pe = _sinusoid_at(pos, cfg.d_model).astype(x.dtype)
            x = x + pe[:, None, :]
        new_cache = []
        for seg, sp, sc in zip(self.plan, params["segments"], cache):
            x, nc = segment_decode(cfg, seg, sp, sc, x, pos)
            new_cache.append(nc)
        x = L.norm_apply(params["final_norm"], x)
        return x @ params["head"], new_cache


def _sinusoid_at(pos: jax.Array, d: int) -> jax.Array:
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-np.log(10000.0) / d))
    ang = pos[:, None].astype(jnp.float32) * div
    out = jnp.zeros((pos.shape[0], d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
