"""Neural layers: norms, RoPE, GQA/MLA attention, MLP, MoE, Mamba2 SSD.

Everything is functional: ``*_specs(cfg)`` builds a Spec pytree,
``*_apply(params, ...)`` runs it.  Attention layers support three modes:
full-sequence (train / prefill), single-token decode against a KV cache,
and sliding-window variants of both.

Logical axes used (mapped to mesh axes in sharding/rules.py):
  'embed'   d_model dims            'ffn'      MLP hidden
  'heads'   attention query heads   'kv_heads' KV heads
  'head_dim'                         'vocab'
  'experts'                          'kv_lora'  MLA latent
  'ssm_head' 'ssm_dim' 'ssm_state'  'layers'   scan stacking
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.init import Spec

PyTree = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ArchConfig, d: int | None = None) -> PyTree:
    d = d or cfg.d_model
    p = {"scale": Spec((d,), ("embed",), "ones")}
    if cfg.norm == "layernorm":
        p["bias"] = Spec((d,), ("embed",), "zeros")
    return p


def norm_apply(params: PyTree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * freqs       # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(S: int, d: int) -> np.ndarray:
    pos = np.arange(S)[:, None]
    div = np.exp(np.arange(0, d, 2) * (-np.log(10000.0) / d))
    out = np.zeros((S, d), np.float32)
    out[:, 0::2] = np.sin(pos * div)
    out[:, 1::2] = np.cos(pos * div)
    return out


# ---------------------------------------------------------------------------
# Scaled-dot-product helpers
# ---------------------------------------------------------------------------

def _sdpa(q, k, v, mask, scale):
    """q:(B,S,H,D) k/v:(B,T,H,D) mask:(B,S,T) or (S,T) broadcastable."""
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    logits = jnp.where(mask[..., None, :, :] if mask.ndim == 3 else mask,
                       logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _sdpa_chunked(q, k, v, scale, *, causal: bool, window: int | None,
                  q_chunk: int):
    """Query-chunked attention: the (S, T) logits tensor is never
    materialized — only (q_chunk, T) tiles inside a lax.scan.  This is the
    jnp analogue of the Pallas flash kernel (kernels/flash_attention) and
    keeps the HBM roofline term O(S·d) instead of O(S²)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    nc = S // q_chunk
    qc = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, D), 1, 0)    # (nc,B,c,H,D)
    kpos = jnp.arange(T)[None, :]

    @jax.checkpoint  # backward recomputes the (c, T) logit tile per chunk
    def chunk_attn(qi, ci):
        logits = jnp.einsum("bshd,bthd->bhst", qi, k).astype(jnp.float32) * scale
        qpos = ci * q_chunk + jnp.arange(q_chunk)[:, None]
        mask = jnp.ones((q_chunk, T), bool)
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
        return jnp.einsum("bhst,bthd->bshd", probs, v)

    def body(_, inp):
        qi, ci = inp                                            # (B,c,H,D), ()
        return None, chunk_attn(qi, ci)

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, v.shape[-1])


def sdpa(q, k, v, scale, *, causal: bool, window: int | None = None,
         q_chunk: int | None = 512):
    """Dispatch: chunked when the query length divides cleanly, full
    otherwise (short sequences / encoder lengths like 1500)."""
    S, T = q.shape[1], k.shape[1]
    if q_chunk and S > q_chunk and S % q_chunk == 0:
        return _sdpa_chunked(q, k, v, scale, causal=causal, window=window,
                             q_chunk=q_chunk)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    return _sdpa(q, k, v, mask, scale)


def causal_mask(S: int, T: int, offset: int = 0,
                window: int | None = None) -> jax.Array:
    """(S, T) mask: query i (global pos offset+i) may see key j iff j <= pos
    and (pos - j) < window."""
    qpos = offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ArchConfig, cross: bool = False) -> PyTree:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": Spec((d, H, hd), ("embed", "heads", "head_dim"), "fan_in"),
        "wk": Spec((d, KV, hd), ("embed", "kv_heads", "head_dim"), "fan_in"),
        "wv": Spec((d, KV, hd), ("embed", "kv_heads", "head_dim"), "fan_in"),
        "wo": Spec((H, hd, d), ("heads", "head_dim", "embed"), "fan_in"),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = Spec((H, hd), ("heads", "head_dim"), "zeros")
        p["bk"] = Spec((KV, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = Spec((KV, hd), ("kv_heads", "head_dim"), "zeros")
    return p


def _qkv(params, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def _expand_kv(k, H):
    KV = k.shape[-2]
    if KV == H:
        return k
    return jnp.repeat(k, H // KV, axis=-2)


def attention_apply(params: PyTree, cfg: ArchConfig, x: jax.Array,
                    positions: jax.Array, *, causal: bool = True,
                    kv_x: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention.  x: (B,S,d).  kv_x (B,T,d) for cross-attn."""
    H, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _qkv(params, x, kv_x)
    if cfg.use_rope and kv_x is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    k, v = _expand_kv(k, H), _expand_kv(v, H)
    is_causal = causal and kv_x is None
    out = sdpa(q, k, v, 1.0 / np.sqrt(hd), causal=is_causal,
               window=cfg.sliding_window if is_causal else None,
               q_chunk=cfg.attn_q_chunk)
    return jnp.einsum("bshd,hdo->bso", out, params["wo"])


def attention_decode(params: PyTree, cfg: ArchConfig, x: jax.Array,
                     pos: jax.Array, cache_k: jax.Array, cache_v: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode.  x: (B,1,d); pos: (B,) current position;
    cache_k/v: (B, C, KV, hd) where C = full seq (dense) or window (SWA).
    Returns (out (B,1,d), cache_k, cache_v)."""
    H, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _qkv(params, x)
    C = cache_k.shape[1]
    if cfg.use_rope:
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % C if cfg.sliding_window else pos               # ring buffer
    bidx = jnp.arange(x.shape[0])
    cache_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    kpos = jnp.arange(C)[None, :]
    if cfg.sliding_window:
        # ring buffer: index r holds global position g, the largest g <= pos
        # with g ≡ r (mod C); valid iff g >= 0 and within the window.
        g = pos[:, None] - ((pos[:, None] - kpos) % C)
        mask = (g >= 0) & (pos[:, None] - g < min(cfg.sliding_window, C))
    else:
        mask = kpos <= pos[:, None]
    # grouped-query attention against the *unexpanded* cache: repeating KV
    # to H heads would materialize an H/KV× copy of the whole cache.
    KV = cache_k.shape[2]
    qg = q[:, 0].reshape(q.shape[0], KV, H // KV, hd)           # (B,KV,G,hd)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, cache_k.astype(x.dtype))
    logits = logits.astype(jnp.float32) / np.sqrt(hd)
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, cache_v.astype(x.dtype))
    out = out.reshape(x.shape[0], 1, H, hd)
    return (jnp.einsum("bshd,hdo->bso", out, params["wo"]), cache_k, cache_v)


def cross_attention_decode(params: PyTree, cfg: ArchConfig, x: jax.Array,
                           cross_k: jax.Array, cross_v: jax.Array) -> jax.Array:
    """Decode-time cross attention against fixed encoder keys/values
    (B, T, KV, hd) — no cache update."""
    H, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    kk = _expand_kv(cross_k.astype(x.dtype), H)
    vv = _expand_kv(cross_v.astype(x.dtype), H)
    T = kk.shape[1]
    mask = jnp.ones((1, 1, T), bool)
    out = _sdpa(q, kk, vv, mask, 1.0 / np.sqrt(hd))
    return jnp.einsum("bshd,hdo->bso", out, params["wo"])


def cross_kv(params: PyTree, enc: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder states (B,T,d)."""
    k = jnp.einsum("btd,dhk->bthk", enc, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    return k, v


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2) [arXiv:2405.04434]
# ---------------------------------------------------------------------------

def mla_specs(cfg: ArchConfig) -> PyTree:
    d, H = cfg.d_model, cfg.num_heads
    r, dr, dn, dv = cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim, cfg.v_head_dim
    return {
        "wq": Spec((d, H, dn + dr), ("embed", "heads", "head_dim"), "fan_in"),
        "w_dkv": Spec((d, r), ("embed", "kv_lora"), "fan_in"),
        "w_kr": Spec((d, dr), ("embed", None), "fan_in"),
        "w_uk": Spec((r, H, dn), ("kv_lora", "heads", "head_dim"), "fan_in"),
        "w_uv": Spec((r, H, dv), ("kv_lora", "heads", "head_dim"), "fan_in"),
        "wo": Spec((H, dv, d), ("heads", "head_dim", "embed"), "fan_in"),
        "kv_norm": {"scale": Spec((r,), ("kv_lora",), "ones")},
    }


def mla_apply(params: PyTree, cfg: ArchConfig, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv = norm_apply(params["kv_norm"], c_kv)
    k_rope = rope(jnp.einsum("bsd,dk->bsk", x, params["w_kr"])[:, :, None, :],
                  positions, cfg.rope_theta)                     # (B,S,1,dr)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
    # Train path reduces to standard attention on concatenated
    # (nope ‖ rope) keys — reuses the chunked flash-style sdpa.
    H = q.shape[2]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, dr))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    scale = 1.0 / np.sqrt(dn + dr)
    # v head dim may differ from qk dim; pad v for the shared kernel? No —
    # sdpa contracts q·k only; v flows through einsum untouched.
    out = sdpa(q_full, k_full, v, scale, causal=True,
               q_chunk=cfg.attn_q_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def mla_decode(params: PyTree, cfg: ArchConfig, x: jax.Array, pos: jax.Array,
               cache_ckv: jax.Array, cache_kr: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Latent-cache decode with the absorption trick: cache only
    (c_kv: (B,C,r), k_rope: (B,C,dr)) — 576 dims/token instead of H*(dn+dv).
    """
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, pos[:, None], cfg.rope_theta)
    # absorb W_uk into the query:  q_eff = q_nope @ W_uk^T  -> latent space
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    c_kv = norm_apply(params["kv_norm"],
                      jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]))
    k_r = rope(jnp.einsum("bsd,dk->bsk", x, params["w_kr"])[:, :, None, :],
               pos[:, None], cfg.rope_theta)[:, :, 0]
    bidx = jnp.arange(x.shape[0])
    cache_ckv = cache_ckv.at[bidx, pos].set(c_kv[:, 0].astype(cache_ckv.dtype))
    cache_kr = cache_kr.at[bidx, pos].set(k_r[:, 0].astype(cache_kr.dtype))
    C = cache_ckv.shape[1]
    mask = (jnp.arange(C)[None, :] <= pos[:, None])[:, None, :]  # (B,1,C)
    scale = 1.0 / np.sqrt(dn + dr)
    logits = (jnp.einsum("bshr,btr->bhst", q_lat, cache_ckv.astype(x.dtype))
              + jnp.einsum("bshk,btk->bhst", q_rope, cache_kr.astype(x.dtype)))
    logits = jnp.where(mask[:, None, :, :],                     # (B,1,1,C)
                       logits.astype(jnp.float32) * scale, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, cache_ckv.astype(x.dtype))
    out = jnp.einsum("bshr,rhk->bshk", out_lat, params["w_uv"])
    return (jnp.einsum("bshk,hkd->bsd", out, params["wo"]),
            cache_ckv, cache_kr)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: int | None = None) -> PyTree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {"w1": Spec((d, f), ("embed", "ffn"), "fan_in"),
                "w3": Spec((d, f), ("embed", "ffn"), "fan_in"),
                "w2": Spec((f, d), ("ffn", "embed"), "fan_in")}
    return {"w1": Spec((d, f), ("embed", "ffn"), "fan_in"),
            "b1": Spec((f,), ("ffn",), "zeros"),
            "w2": Spec((f, d), ("ffn", "embed"), "fan_in"),
            "b2": Spec((d,), ("embed",), "zeros")}


def mlp_apply(params: PyTree, x: jax.Array) -> jax.Array:
    if "w3" in params:
        h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
        return h @ params["w2"]
    h = jax.nn.gelu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


# ---------------------------------------------------------------------------
# MoE — sort-based token-choice top-k with per-group capacity
# ---------------------------------------------------------------------------

def moe_specs(cfg: ArchConfig) -> PyTree:
    d, f, E = cfg.d_model, cfg.moe_hidden, cfg.num_experts
    p = {
        "router": Spec((d, E), ("embed", None), "fan_in"),
        "w1": Spec((E, d, f), ("experts", "embed", "ffn"), "fan_in"),
        "w3": Spec((E, d, f), ("experts", "embed", "ffn"), "fan_in"),
        "w2": Spec((E, f, d), ("experts", "ffn", "embed"), "fan_in"),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_specs(cfg, cfg.moe_hidden * cfg.num_shared_experts)
    return p


def _route_group(logits: jax.Array, k: int, E: int, C: int):
    """Per-group routing.  logits: (G, E).  Returns (dispatch_idx (E*C,),
    valid (E*C,), combine_w (E*C,)) where dispatch_idx points into tokens."""
    G = logits.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                      # (G, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    flat_e = top_e.reshape(-1)                                  # (G*k,)
    flat_w = top_w.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(G), k)
    order = jnp.argsort(flat_e, stable=True)                    # group by expert
    se, st, sw = flat_e[order], flat_tok[order], flat_w[order]
    # position of each routed pair within its expert
    pos_in_e = jnp.arange(G * k) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < C
    dest = jnp.where(keep, se * C + pos_in_e, E * C)            # drop slot
    buf_tok = jnp.full((E * C + 1,), G, jnp.int32).at[dest].set(st.astype(jnp.int32))[:-1]
    buf_w = jnp.zeros((E * C + 1,), jnp.float32).at[dest].set(sw)[:-1]
    return buf_tok, buf_w


def moe_apply_sorted(params: PyTree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Sort/gather dispatch (decode path: S small).  At prefill/train
    lengths the per-group argsort+gather defeats GSPMD's batch sharding —
    measured 80 GiB all-gathers per MoE layer on jamba prefill — so long
    sequences use :func:`moe_apply_einsum` instead."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = max(1, int(S * k * cfg.moe_capacity_factor / E))
    router_dtype = jnp.float32 if cfg.moe_router_dtype == "float32" else x.dtype

    def group(xg):                                              # (S, d)
        logits = xg.astype(router_dtype) @ params["router"].astype(router_dtype)
        buf_tok, buf_w = _route_group(logits, k, E, C)
        xpad = jnp.concatenate([xg, jnp.zeros((1, d), xg.dtype)], axis=0)
        xe = xpad[buf_tok].reshape(E, C, d)                     # gather
        h = jnp.einsum("ecd,edf->ecf", xe, params["w1"])
        g = jnp.einsum("ecd,edf->ecf", xe, params["w3"])
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * g, params["w2"])
        ye = ye.reshape(E * C, d) * buf_w[:, None].astype(xg.dtype)
        y = jnp.zeros((S + 1, d), xg.dtype).at[buf_tok].add(ye)[:-1]
        return y

    return jax.vmap(group)(x)


def moe_apply_einsum(params: PyTree, cfg: ArchConfig, x: jax.Array,
                     group_size: int = 2048) -> jax.Array:
    """GShard-style one-hot dispatch/combine einsums over token subgroups.

    Every step is an einsum, so SPMD keeps the batch/group dims sharded
    (unlike sort+gather).  Dispatch overhead: 2·gs·k·E·C·d ≈ 10% of the
    expert GEMMs at gs=2048, cap 1.25.  Identical outputs to the sorted
    path under ample capacity (tested); drop *sets* differ only when over
    capacity (sorted drops by expert-sorted order, this by token order —
    both are valid GShard semantics).
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    gs = min(group_size, S)
    ng = S // gs
    C = max(1, int(gs * k * cfg.moe_capacity_factor / E))
    router_dtype = jnp.float32 if cfg.moe_router_dtype == "float32" else x.dtype

    xg = x.reshape(B, ng, gs, d)
    logits = jnp.einsum("bnsd,de->bnse", xg.astype(router_dtype),
                        params["router"].astype(router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                      # (B,ng,gs,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # flatten the k choices into the token axis (token-major order)
    oh = jax.nn.one_hot(top_e, E, dtype=jnp.float32)            # (B,ng,gs,k,E)
    ohf = oh.reshape(B, ng, gs * k, E)
    pos = jnp.cumsum(ohf, axis=2) - ohf                         # slot within expert
    pos_sel = jnp.sum(pos * ohf, axis=-1)                       # (B,ng,gs*k)
    keep = (pos_sel < C).astype(jnp.float32)
    slot_oh = jax.nn.one_hot(pos_sel.astype(jnp.int32), C,
                             dtype=jnp.float32)                 # (B,ng,gs*k,C)
    dispatch = jnp.einsum("bnse,bnsc->bnsec", ohf * keep[..., None], slot_oh)
    wf = top_w.reshape(B, ng, gs * k).astype(jnp.float32)
    combine_w = dispatch * wf[..., None, None]                  # (B,ng,gs*k,E,C)
    xrep = jnp.repeat(xg, k, axis=2)                            # (B,ng,gs*k,d)
    xe = jnp.einsum("bnsec,bnsd->bnecd", dispatch.astype(x.dtype), xrep)
    h = jnp.einsum("bnecd,edf->bnecf", xe, params["w1"])
    g = jnp.einsum("bnecd,edf->bnecf", xe, params["w3"])
    ye = jnp.einsum("bnecf,efd->bnecd", jax.nn.silu(h) * g, params["w2"])
    y = jnp.einsum("bnsec,bnecd->bnsd", combine_w.astype(x.dtype), ye)
    # sum the k duplicated choices back per token
    y = y.reshape(B, ng, gs, k, d).sum(axis=3)
    return y.reshape(B, S, d)


def moe_load_balance_loss(params: PyTree, cfg: ArchConfig,
                          x: jax.Array) -> jax.Array:
    """Switch-style router auxiliary loss: E · Σ_e f_e · p_e, where f_e is
    the fraction of tokens whose top-1 choice is expert e and p_e the mean
    router probability.  Minimized (=1) at a uniform distribution —
    production MoE meta-training adds `moe_aux_weight ×` this per MoE layer
    to keep routed experts from collapsing under per-agent task skew.
    (Opt-in: not wired into the baseline loss so §Roofline tables stay
    paper-faithful; see `examples/decentralized_lm.py --moe` usage note.)
    """
    E = cfg.num_experts
    router_dtype = jnp.float32 if cfg.moe_router_dtype == "float32" else x.dtype
    logits = jnp.einsum("bsd,de->bse", x.astype(router_dtype),
                        params["router"].astype(router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)                     # (B,S,E)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
    p = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(f * p)


def moe_apply(params: PyTree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, d).  Dispatch path per cfg.moe_dispatch:

      'sorted'  sort/gather (training: the one-hot einsums cost ~2× extra
                under backward; and for high-k/small-f MoEs like DeepSeek
                the dispatch einsum alone exceeds the expert GEMMs)
      'einsum'  GShard one-hot dispatch (inference: shards cleanly, no
                batch-replicating gathers — measured −75% FLOPs/dev and
                −91% wire on jamba/mixtral prefill_32k)
      'auto'    einsum iff the dispatch/expert flop ratio (2/3)·gs·k/f < 0.5
                and the length divides the group size
    """
    S = x.shape[1]
    mode = cfg.moe_dispatch
    gs = 2048 if S % 2048 == 0 else (1024 if S % 1024 == 0 else 0)
    if mode == "auto":
        ratio = (2 / 3) * (gs * cfg.experts_per_token) / max(1, cfg.moe_hidden)
        mode = "einsum" if (gs and ratio < 0.5) else "sorted"
    if mode == "einsum" and gs:
        y = moe_apply_einsum(params, cfg, x, group_size=gs)
    else:
        y = moe_apply_sorted(params, cfg, x)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x)
    return y


# ---------------------------------------------------------------------------
# Mamba2 — SSD (state-space duality) chunked scan [arXiv:2405.21060]
# ---------------------------------------------------------------------------

def mamba2_specs(cfg: ArchConfig) -> PyTree:
    d = cfg.d_model
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    cw = cfg.ssm_conv
    return {
        "w_x": Spec((d, H, P), ("embed", "ssm_head", "ssm_dim"), "fan_in"),
        "w_z": Spec((d, H, P), ("embed", "ssm_head", "ssm_dim"), "fan_in"),
        "w_B": Spec((d, G, N), ("embed", None, "ssm_state"), "fan_in"),
        "w_C": Spec((d, G, N), ("embed", None, "ssm_state"), "fan_in"),
        "w_dt": Spec((d, H), ("embed", "ssm_head"), "fan_in"),
        "dt_bias": Spec((H,), ("ssm_head",), "zeros"),
        "A_log": Spec((H,), ("ssm_head",), "zeros"),
        "D": Spec((H,), ("ssm_head",), "ones"),
        "conv_x": Spec((cw, H, P), (None, "ssm_head", "ssm_dim"), "fan_in"),
        "conv_B": Spec((cw, G, N), (None, None, "ssm_state"), "fan_in"),
        "conv_C": Spec((cw, G, N), (None, None, "ssm_state"), "fan_in"),
        "norm": {"scale": Spec((H, P), ("ssm_head", "ssm_dim"), "ones")},
        "w_out": Spec((H, P, d), ("ssm_head", "ssm_dim", "embed"), "fan_in"),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along time.  x: (B, L, *ch); w: (cw, *ch)."""
    cw = w.shape[0]
    pad = jnp.zeros(x.shape[:1] + (cw - 1,) + x.shape[2:], x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    return jax.nn.silu(out)


def _gated_rmsnorm(scale, x, z, eps=1e-6):
    x = x * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_scan(x, dt, A, B, C, chunk: int,
              init_state: jax.Array | None = None):
    """Chunked SSD.  x: (B,L,H,P), dt: (B,L,H), A: (H,) (<0), B/C: (B,L,G,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N)).

    jnp analogue of kernels/ssd_scan: a lax.scan over chunks carrying the
    (B,H,P,N) state.  Only ONE chunk's (c,c,H) decay tile is live at a time
    — materializing all chunks at once costs O(L·c·H) extra HBM (measured
    2.8 TiB/device on jamba-398B's 256-head mixers before this layout).
    """
    Bb, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    nc = L // chunk
    rep = H // G
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def to_chunks(a):
        return jnp.moveaxis(a.reshape((Bb, nc, chunk) + a.shape[2:]), 1, 0)

    xs = (to_chunks(x), to_chunks(dt.astype(jnp.float32)),
          to_chunks(B), to_chunks(C))

    @jax.checkpoint   # backward recomputes the (c,c,H) decay tile per chunk
    def body(state, inp):
        xc, dtc, Bg, Cg = inp            # (B,c,H,P) (B,c,H) (B,c,G,N) ...
        Bc = jnp.repeat(Bg, rep, axis=2)                        # (B,c,H,N)
        Cc = jnp.repeat(Cg, rep, axis=2)
        dA = dtc * A                                            # (B,c,H) ≤ 0
        seg = jnp.cumsum(dA, axis=1)
        li = seg[:, :, None, :] - seg[:, None, :, :]            # (B,cq,ck,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(li), 0.0)
        cb = jnp.einsum("bqhn,bkhn->bqkh", Cc, Bc)
        M = (cb * decay * dtc[:, None, :, :]).astype(x.dtype)
        y = jnp.einsum("bqkh,bkhp->bqhp", M, xc)                # intra-chunk
        y += jnp.exp(seg)[..., None].astype(x.dtype) * jnp.einsum(
            "bqhn,bhpn->bqhp", Cc, state)                       # entering state
        end = seg[:, -1:, :]
        w = (jnp.exp(end - seg) * dtc).astype(x.dtype)          # (B,c,H)
        new_state = (state * jnp.exp(end[:, 0])[..., None, None].astype(x.dtype)
                     + jnp.einsum("bkh,bkhn,bkhp->bhpn", w, Bc, xc))
        return new_state, y

    s0 = (jnp.zeros((Bb, H, P, N), x.dtype) if init_state is None
          else init_state.astype(x.dtype))
    final, ys = jax.lax.scan(body, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, L, H, P)
    return y, final


def mamba2_apply(params: PyTree, cfg: ArchConfig, x: jax.Array,
                 use_kernel: bool = False) -> jax.Array:
    """Full-sequence Mamba2 mixer.  x: (B, L, d)."""
    xin = jnp.einsum("bld,dhp->blhp", x, params["w_x"])
    z = jnp.einsum("bld,dhp->blhp", x, params["w_z"])
    Bm = jnp.einsum("bld,dgn->blgn", x, params["w_B"])
    Cm = jnp.einsum("bld,dgn->blgn", x, params["w_C"])
    xin = _causal_conv(xin, params["conv_x"])
    Bm = _causal_conv(Bm, params["conv_B"])
    Cm = _causal_conv(Cm, params["conv_C"])
    dt = jax.nn.softplus(jnp.einsum("bld,dh->blh", x, params["w_dt"])
                         + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    L = x.shape[1]
    chunk = min(cfg.ssm_chunk, L)
    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, _ = ssd_ops.ssd_scan(xin, dt, A, Bm, Cm, chunk=chunk)
    else:
        y, _ = ssd_scan(xin, dt.astype(jnp.float32), A, Bm, Cm, chunk)
    y = y + xin * params["D"][None, None, :, None]
    y = _gated_rmsnorm(params["norm"]["scale"], y, z)
    return jnp.einsum("blhp,hpd->bld", y, params["w_out"])


def mamba2_decode(params: PyTree, cfg: ArchConfig, x: jax.Array,
                  conv_state: jax.Array, ssm_state: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token recurrent step.  x: (B,1,d);
    conv_state: (B, cw-1, H*P + 2*G*N) flattened channel history;
    ssm_state: (B, H, P, N)."""
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    cw = cfg.ssm_conv
    xin = jnp.einsum("bld,dhp->blhp", x, params["w_x"])[:, 0]   # (B,H,P)
    z = jnp.einsum("bld,dhp->blhp", x, params["w_z"])[:, 0]
    Bm = jnp.einsum("bld,dgn->blgn", x, params["w_B"])[:, 0]
    Cm = jnp.einsum("bld,dgn->blgn", x, params["w_C"])[:, 0]
    Bsz = x.shape[0]
    ch = jnp.concatenate([xin.reshape(Bsz, -1), Bm.reshape(Bsz, -1),
                          Cm.reshape(Bsz, -1)], axis=-1)        # (B, ch)
    hist = jnp.concatenate([conv_state, ch[:, None, :]], axis=1)  # (B,cw,ch)
    wx = params["conv_x"].reshape(cw, -1)
    wB = params["conv_B"].reshape(cw, -1)
    wC = params["conv_C"].reshape(cw, -1)
    wall = jnp.concatenate([wx, wB, wC], axis=-1)               # (cw, ch)
    conved = jax.nn.silu(jnp.einsum("bcw,cw->bw", hist, wall))
    xin = conved[:, : H * P].reshape(Bsz, H, P)
    Bm = conved[:, H * P: H * P + G * N].reshape(Bsz, G, N)
    Cm = conved[:, H * P + G * N:].reshape(Bsz, G, N)
    dt = jax.nn.softplus(jnp.einsum("bld,dh->blh", x, params["w_dt"])[:, 0]
                         + params["dt_bias"])                   # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                            # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    decay = jnp.exp(dt * A)[..., None, None]                    # (B,H,1,1)
    upd = dt[..., None, None] * jnp.einsum("bhn,bhp->bhpn", Bh, xin)
    ssm_state = ssm_state * decay.astype(ssm_state.dtype) + upd.astype(ssm_state.dtype)
    y = jnp.einsum("bhpn,bhn->bhp", ssm_state.astype(x.dtype), Ch)
    y = y + xin * params["D"][None, :, None]
    y = _gated_rmsnorm(params["norm"]["scale"], y, z)
    out = jnp.einsum("bhp,hpd->bd", y, params["w_out"])[:, None, :]
    return out, hist[:, 1:], ssm_state
