from repro.models.init import Spec, materialize, axes_tree, count_params
from repro.models import layers, transformer

__all__ = ["Spec", "materialize", "axes_tree", "count_params", "layers", "transformer"]
