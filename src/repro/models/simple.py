"""The paper's own models: sine-regression MLP and few-shot conv net."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.init import Spec, materialize

PyTree = Any


class SineMLP:
    """2 hidden layers × `width` ReLU units (paper App. D.1)."""

    def __init__(self, cfg: ArchConfig):
        self.width = cfg.d_model
        self.depth = cfg.num_layers

    def specs(self) -> PyTree:
        w = self.width
        dims = [1] + [w] * self.depth + [1]
        # Finn et al. 2017 use ~N(0, 0.01) weights; larger inits make the
        # α=0.01 inner step unstable on the raw x ∈ [-5, 5] inputs.
        return {f"l{i}": {"w": Spec((dims[i], dims[i + 1]), ("embed", "ffn"), "normal", 0.5),
                          "b": Spec((dims[i + 1],), ("ffn",), "zeros")}
                for i in range(len(dims) - 1)}

    def init(self, key, dtype=jnp.float32):
        return materialize(self.specs(), key, dtype)

    def forward(self, params: PyTree, x: jax.Array) -> jax.Array:
        n = self.depth + 1
        for i in range(n):
            x = x @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
            if i < n - 1:
                x = jax.nn.relu(x)
        return x

    def loss_fn(self, params: PyTree, batch) -> jax.Array:
        x, y = batch
        return jnp.mean((self.forward(params, x) - y) ** 2)


class FewShotCNN:
    """Conv blocks (3×3, stride 1, 2×2 maxpool) + linear head; operates on
    flattened (hw*hw,) synthetic images (data/fewshot.py)."""

    def __init__(self, cfg: ArchConfig, image_hw: int = 14):
        self.ch = cfg.d_model
        self.blocks = cfg.num_layers
        self.n_way = cfg.vocab_size
        self.hw = image_hw

    def specs(self) -> PyTree:
        p = {}
        cin, hw = 1, self.hw
        for i in range(self.blocks):
            p[f"conv{i}"] = {
                "w": Spec((3, 3, cin, self.ch), (None, None, None, "ffn"), "fan_in", 0.5),
                "b": Spec((self.ch,), ("ffn",), "zeros"),
            }
            cin, hw = self.ch, hw // 2
        p["head"] = {"w": Spec((hw * hw * self.ch, self.n_way), ("embed", None), "fan_in", 0.3),
                     "b": Spec((self.n_way,), (None,), "zeros")}
        return p

    def init(self, key, dtype=jnp.float32):
        return materialize(self.specs(), key, dtype)

    def forward(self, params: PyTree, x: jax.Array) -> jax.Array:
        B = x.shape[0]
        h = x.reshape(B, self.hw, self.hw, 1)
        for i in range(self.blocks):
            h = jax.lax.conv_general_dilated(
                h, params[f"conv{i}"]["w"], (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            h = h + params[f"conv{i}"]["b"]
            h = jax.nn.relu(h)
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        h = h.reshape(B, -1)
        return h @ params["head"]["w"] + params["head"]["b"]

    def loss_fn(self, params: PyTree, batch) -> jax.Array:
        x, y = batch
        logits = self.forward(params, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    def accuracy(self, params: PyTree, batch) -> jax.Array:
        x, y = batch
        return jnp.mean((jnp.argmax(self.forward(params, x), -1) == y)
                        .astype(jnp.float32))
