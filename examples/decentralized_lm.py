"""End-to-end driver: decentralized meta-training of a ~100M-parameter LM.

Each agent holds a disjoint shard of synthetic text *domains*
(``LMTaskSource`` — heterogeneous π_k, with one domain held out for the
unseen-task eval); one Dif-MAML iteration adapts to sampled domains (inner
step), takes the meta-gradient on held-out batches (outer), and diffuses
launch models over a ring.  Episodes are generated in one vectorized pass
and prefetched on a background thread (``bundle.make_pipeline``) so the
host samples step i+1 while the device runs step i.  This is the
production analogue of the paper's heterogeneous-task experiment, built on
the same launch/steps.py bundles the dry-run lowers for the 256-chip mesh.

Default geometry (~100M params: 12L × d512 × ffn2048 × 32k vocab):
  PYTHONPATH=src python examples/decentralized_lm.py --steps 300
CPU smoke (seconds):
  PYTHONPATH=src python examples/decentralized_lm.py --tiny --steps 4
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.checkpoint import save_checkpoint
from repro.configs.base import ArchConfig, InputShape
from repro.core import topology, update
from repro.data import LMTaskSource
from repro.launch.mesh import make_host_mesh
from repro.launch import steps as S
from repro.models.init import count_params
from repro.models.transformer import build_model


def lm_100m(tiny: bool) -> ArchConfig:
    if tiny:
        return ArchConfig(
            name="lm-tiny", arch_type="dense", num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
            vocab_size=512, meta_mode="maml", topology="ring",
            outer_optimizer="adam", dtype="float32", remat=False,
            attn_q_chunk=None)
    return ArchConfig(
        name="lm-100m", arch_type="dense", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32768, meta_mode="maml", topology="ring",
        outer_optimizer="adam", dtype="float32", remat=False,
        attn_q_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--strategy", default=None,
                    choices=sorted(update.update_strategies()),
                    help="outer-update strategy (default atc)")
    ap.add_argument("--schedule", default="static",
                    choices=sorted(topology.SCHEDULES),
                    help="per-step topology schedule")
    ap.add_argument("--link-failure-p", type=float, default=0.2,
                    help="per-edge drop probability for --schedule "
                         "link_failure")
    args = ap.parse_args()

    cfg = lm_100m(args.tiny)
    seq = args.seq or (32 if args.tiny else 256)
    gb = args.global_batch or (8 if args.tiny else 32)
    shape = InputShape("lm_example", seq, gb, "train")

    mesh = make_host_mesh(data=min(4, len(jax.devices())))
    with mesh:
        bundle = S.build_train(cfg, mesh, shape,
                               strategy=args.strategy,
                               schedule=args.schedule,
                               link_failure_p=args.link_failure_p)
        model = build_model(cfg)
        n = count_params(model.specs())
        print(f"[lm] {cfg.name}: {n/1e6:.1f}M params, K={bundle.K} agents, "
              f"T={bundle.T}×{bundle.tb} tasks, seq={seq}, batch={gb}, "
              f"strategy={bundle.mcfg.update_config.strategy}"
              + (f" ({args.schedule} schedule)"
                 if args.schedule != "static" else ""))
        state = bundle.init_state(seed=0)
        step = jax.jit(bundle.step_fn, donate_argnums=(0,))
        source = LMTaskSource(
            vocab_size=cfg.padded_vocab, seq_len=seq, K=bundle.K,
            tasks_per_agent=bundle.T, task_batch=bundle.tb,
            n_domains=8 * max(1, bundle.K), holdout_domains=1, seed=0)
        print(f"[lm] {source.heterogeneity}: {source.n_train_domains} train "
              f"domains sharded across agents, {source.holdout_domains} "
              f"held out for eval, prefetch depth {args.prefetch}")
        t0 = time.time()
        with bundle.make_pipeline(source, depth=args.prefetch) as pipe:
            for i in range(args.steps):
                state, m = step(state, next(pipe))
                if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                    print(f"step {int(state.step):4d} meta-loss "
                          f"{float(m['loss']):.4f} disagreement "
                          f"{float(m['disagreement']):.2e} "
                          f"({time.time()-t0:.1f}s)")
        dt = time.time() - t0
        print(f"[lm] {args.steps} steps in {dt:.1f}s "
              f"({args.steps / dt:.2f} episodes/s end-to-end)")
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, int(state.step), state)
            print(f"[lm] checkpoint saved to {args.ckpt_dir}")

        # post-training: the recurring-vs-unseen protocol through the same
        # EvalHarness the trainer hook and the serve path use
        harness = bundle.make_eval_harness(inner_steps=1)
        report = harness.evaluate(state, source, n_tasks=1, seed=10_001)
        for split, rep in report.splits.items():
            c = rep.centroid_curve
            print(f"[lm] {split} loss: zero-shot {c[0]:.4f} "
                  f"→ one adaptation step {c[-1]:.4f}")
        print(f"[lm] generalization gap (unseen − recurring, adapted): "
              f"{report.generalization_gap:.4f}")


if __name__ == "__main__":
    main()
