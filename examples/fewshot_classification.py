"""Few-shot classification with Dif-MAML (paper §4.2 analogue).

Synthetic Omniglot-surrogate episodes (the real archives are not available
offline; see data/fewshot.py) through the unified ``FewShotTaskSource``:
each agent owns a disjoint shard of the meta-train classes (heterogeneous
π_k), and evaluation episodes come from the meta-test classes nobody
trained on.  Compares the three strategies of the paper: centralized /
Dif-MAML / non-cooperative, 5-way 1-shot.

  PYTHONPATH=src python examples/fewshot_classification.py [--steps 150]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (MetaConfig, TopologyConfig, UpdateConfig, diffusion,
                        init_state, make_meta_step)
from repro.data import Episode, FewShotTaskSource, MetaBatchPipeline
from repro.models.simple import FewShotCNN


def test_accuracy(model, params, source, inner_lr, n_tasks=50):
    ep = source.eval_sample(n_tasks, seed=777)   # meta-test classes
    (sx, sy), (qx, qy) = ep.support, ep.query

    def adapted_acc(sx_, sy_, qx_, qy_):
        g = jax.grad(model.loss_fn)(params, (sx_, sy_))
        pa = jax.tree.map(lambda a, b: a - inner_lr * b, params, g)
        return model.accuracy(pa, (qx_, qy_))

    return float(jnp.mean(jax.vmap(adapted_acc)(
        jnp.asarray(sx), jnp.asarray(sy), jnp.asarray(qx), jnp.asarray(qy))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--prefetch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("omniglot_cnn")
    source = FewShotTaskSource(K=6, tasks_per_agent=2, n_classes=80,
                               n_way=cfg.vocab_size, k_shot=1, n_query=5,
                               seed=0)
    model = FewShotCNN(cfg, image_hw=source.image_hw)
    print(f"{source.heterogeneity}: {source.n_domains} meta-train classes "
          f"sharded across K={source.K} agents, eval on "
          f"{source.n_test_domains} meta-test classes")

    for label, strategy in [("centralized", "centralized"),
                            ("dif-maml", "atc"),
                            ("non-coop", "none")]:
        mcfg = MetaConfig(num_agents=6, tasks_per_agent=2,
                          inner_lr=cfg.inner_lr,
                          update_config=UpdateConfig(strategy=strategy,
                                                     inner="maml"),
                          topology_config=TopologyConfig(graph="paper"),
                          outer_optimizer="adam", outer_lr=1e-3)
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=True)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        with MetaBatchPipeline(source, depth=args.prefetch,
                               prepare=Episode.to_device) as pipe:
            for i in range(args.steps):
                sup, qry = next(pipe)
                state, m = step(state, sup, qry)
        centroid = diffusion.centroid(state.params)
        acc = test_accuracy(model, centroid, source, cfg.inner_lr)
        print(f"{label:12s} meta-train loss {float(m['loss']):.3f}   "
              f"5-way 1-shot test acc {acc:.3f}")


if __name__ == "__main__":
    main()
