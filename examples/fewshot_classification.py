"""Few-shot classification with Dif-MAML (paper §4.2 analogue).

Synthetic Omniglot-surrogate episodes (the real archives are not available
offline; see data/fewshot.py).  Compares the three strategies of the paper:
centralized / Dif-MAML / non-cooperative, 5-way 1-shot.

  PYTHONPATH=src python examples/fewshot_classification.py [--steps 150]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import MetaConfig, diffusion, init_state, make_meta_step
from repro.data.fewshot import FewShotSampler
from repro.models.simple import FewShotCNN


def test_accuracy(model, params, sampler, inner_lr, n_tasks=50):
    (sx, sy), (qx, qy) = sampler.sample(n_tasks, split="test", seed=777)

    def adapted_acc(sx_, sy_, qx_, qy_):
        g = jax.grad(model.loss_fn)(params, (sx_, sy_))
        pa = jax.tree.map(lambda a, b: a - inner_lr * b, params, g)
        return model.accuracy(pa, (qx_, qy_))

    return float(jnp.mean(jax.vmap(adapted_acc)(
        jnp.asarray(sx), jnp.asarray(sy), jnp.asarray(qx), jnp.asarray(qy))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    args = ap.parse_args()

    cfg = get_config("omniglot_cnn")
    sampler = FewShotSampler(n_classes=80, n_way=cfg.vocab_size,
                             k_shot=1, n_query=5, seed=0)
    model = FewShotCNN(cfg, image_hw=sampler.image_hw)

    for strat, combine in [("centralized", "centralized"),
                           ("dif-maml", "dense"),
                           ("non-coop", "none")]:
        mcfg = MetaConfig(num_agents=6, tasks_per_agent=2,
                          inner_lr=cfg.inner_lr, mode="maml",
                          combine=combine, topology="paper",
                          outer_optimizer="adam", outer_lr=1e-3)
        state = init_state(jax.random.key(0), model.init, mcfg,
                           identical_init=True)
        step = jax.jit(make_meta_step(model.loss_fn, mcfg))
        for i in range(args.steps):
            sup, qry = sampler.sample_agents(6, 2)
            state, m = step(state, jax.tree.map(jnp.asarray, sup),
                            jax.tree.map(jnp.asarray, qry))
        centroid = diffusion.centroid(state.params)
        acc = test_accuracy(model, centroid, sampler, cfg.inner_lr)
        print(f"{strat:12s} meta-train loss {float(m['loss']):.3f}   "
              f"5-way 1-shot test acc {acc:.3f}")


if __name__ == "__main__":
    main()
