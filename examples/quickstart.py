"""Quickstart: Dif-MAML on the paper's sine-regression benchmark (§4.1).

Six agents, each seeing a different amplitude band of the task universe
(``SineTaskSource`` shards the bands — heterogeneous π_k), cooperate over
the paper's Fig. 2a graph and jointly meta-learn a launch model that adapts
to *any* sinusoid in one gradient step.  Episodes stream through the
``MetaBatchPipeline`` prefetcher so sampling overlaps the jitted step.

The outer update is assembled from the three first-class axes: a
``DiffusionStrategy`` (``--strategy``: atc is the paper's Algorithm 1, cta
and consensus its classic alternatives), a ``TopologySchedule``
(``--schedule``: static / link_failure / gossip / round_robin), and the
graph itself (``--topology``).

  PYTHONPATH=src python examples/quickstart.py [--steps 400] \\
      [--strategy cta] [--schedule link_failure]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (MetaConfig, TopologyConfig, UpdateConfig, diffusion,
                        init_state, make_eval_fn, make_meta_step, topology,
                        update)
from repro.core.meta_trainer import schedule_for
from repro.data import Episode, MetaBatchPipeline, SineTaskSource
from repro.models.simple import SineMLP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--agents", type=int, default=6)
    ap.add_argument("--topology", default="paper")
    ap.add_argument("--strategy", default="atc",
                    choices=sorted(update.update_strategies()))
    ap.add_argument("--schedule", default="static",
                    choices=sorted(topology.SCHEDULES))
    ap.add_argument("--link-failure-p", type=float, default=0.2)
    ap.add_argument("--prefetch", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config("sine_mlp")
    model = SineMLP(cfg)
    K = args.agents
    mcfg = MetaConfig(
        num_agents=K, tasks_per_agent=5, inner_lr=cfg.inner_lr,
        outer_optimizer="adam", outer_lr=1e-3,
        update_config=UpdateConfig(strategy=args.strategy, inner="maml"),
        topology_config=TopologyConfig(
            graph=args.topology if K == 6 else "ring",
            schedule=args.schedule, link_failure_p=args.link_failure_p))
    sched = schedule_for(mcfg)
    source = SineTaskSource(K=K, tasks_per_agent=5, shots=10, seed=0)
    print(f"K={K} agents, strategy={args.strategy} on "
          f"'{sched.topology.name}' graph ({sched.kind} schedule, period "
          f"{sched.period}), mean λ₂={sched.mean_mixing_rate:.3f} "
          f"(mixing rate, Thm 1); {source.heterogeneity}: "
          f"{source.n_domains} amplitude bands sharded across agents")

    state = init_state(jax.random.key(0), model.init, mcfg,
                       identical_init=True)
    step = jax.jit(make_meta_step(model.loss_fn, mcfg))
    evaln = make_eval_fn(model.loss_fn, inner_lr=cfg.inner_lr, inner_steps=5)
    ev = source.eval_sample(200, seed=999)      # full amplitude range
    esup = jax.tree.map(jnp.asarray, ev.support)
    eqry = jax.tree.map(jnp.asarray, ev.query)

    with MetaBatchPipeline(source, depth=args.prefetch,
                           prepare=Episode.to_device) as pipe:
        for i in range(args.steps):
            support, query = next(pipe)
            state, metrics = step(state, support, query)
            if i % 50 == 0 or i == args.steps - 1:
                c = diffusion.centroid(state.params)
                curve = np.asarray(evaln(c, esup, eqry)).mean(0)
                print(f"step {i:4d}  train-loss {float(metrics['loss']):.4f}  "
                      f"disagreement {float(metrics['disagreement']):.2e}  "
                      f"eval 0-shot {curve[0]:.3f} → 1-step {curve[1]:.3f} "
                      f"→ 5-step {curve[5]:.3f}")
    print("done: the launch model adapts to unseen amplitudes in one step.")


if __name__ == "__main__":
    main()
