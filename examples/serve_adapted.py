"""Adapt-then-serve, end-to-end on the unified TaskSource surface.

The product of Dif-MAML is a launch model that specializes fast.  This
example reproduces the full production path on CPU:

  1. meta-train a reduced config for a few steps, checkpointing the
     K-agent ``TrainState`` (``launch/train.py``);
  2. restore the checkpoint's **centroid** launch model
     (``checkpoint.restore_centroid`` — mean over the agent axis);
  3. adapt it to an unseen-domain ``eval_sample`` episode through the
     shared engine (``maml.inner_adapt``, via ``launch/serve.py``);
  4. serve batched decode requests from the adapted weights.

  PYTHONPATH=src python examples/serve_adapted.py [--arch qwen2-1.5b]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--train-steps", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args, rest = ap.parse_known_args()

    ckpt_root = tempfile.mkdtemp(prefix="serve_adapted_")
    print(f"== meta-train {args.train_steps} steps -> checkpoint "
          f"({ckpt_root}) ==")
    sys.argv = ["train", "--arch", args.arch, "--reduced",
                "--steps", str(args.train_steps), "--seq", "16",
                "--global-batch", "16", "--agents", "4",
                "--seed", str(args.seed), "--ckpt-dir", ckpt_root,
                "--run-log", os.path.join(ckpt_root, "run.jsonl")]
    train_main()

    print("== adapt the checkpoint centroid to an unseen domain, "
          "then serve ==")
    sys.argv = ["serve", "--arch", args.arch, "--reduced",
                "--seed", str(args.seed),
                "--ckpt-dir", os.path.join(ckpt_root, f"seed{args.seed}"),
                "--batch", "4", "--prompt-len", "8", "--gen", "16",
                "--adapt-steps", "2"] + rest
    serve_main()
