"""Adapt-then-serve example (thin wrapper over launch/serve.py).

The product of Dif-MAML is a launch model that specializes fast: this
example adapts it to a synthetic domain with 2 gradient steps, then serves
a batch of decode requests from the adapted weights.

  PYTHONPATH=src python examples/serve_adapted.py [--arch qwen2-1.5b]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    args, rest = ap.parse_known_args()
    sys.argv = ["serve", "--arch", args.arch, "--reduced",
                "--batch", "4", "--prompt-len", "8", "--gen", "16",
                "--adapt-steps", "2"] + rest
    serve_main()
